"""Docstring lint for the public API surface (``make docs-check``).

Walks the AST of every module under the given roots (default:
``src/repro/core`` and ``src/repro/kernels``) and fails if any *public*
symbol lacks a docstring:

* the module itself;
* module-level functions and classes not prefixed with ``_``;
* public methods of public classes (dunders other than ``__call__`` are
  exempt, as are ``@property`` bodies of dataclass field wrappers — i.e.
  nothing is exempt except underscore names and dunders).

Usage::

    python tools/check_docstrings.py [root ...]
"""

from __future__ import annotations

import ast
import pathlib
import sys

DEFAULT_ROOTS = [
    "src/repro/core",
    "src/repro/kernels",
    "src/repro/sharding",
    "src/repro/launch",
    "src/repro/serve",
    "src/repro/data",
    "src/repro/train",
    "src/repro/optim",
]

FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)


def _is_public(name: str) -> bool:
    if name.startswith("__") and name.endswith("__"):
        return name == "__call__"  # documented operator surface
    return not name.startswith("_")


def check_module(path: pathlib.Path) -> list[str]:
    """Return 'path:line: message' entries for every missing docstring."""
    tree = ast.parse(path.read_text(), filename=str(path))
    missing: list[str] = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{path}:1: module docstring missing")
    for node in tree.body:
        if isinstance(node, FuncDef) and _is_public(node.name):
            if ast.get_docstring(node) is None:
                missing.append(
                    f"{path}:{node.lineno}: function `{node.name}` undocumented"
                )
        elif isinstance(node, ast.ClassDef) and _is_public(node.name):
            if ast.get_docstring(node) is None:
                missing.append(
                    f"{path}:{node.lineno}: class `{node.name}` undocumented"
                )
            for sub in node.body:
                if isinstance(sub, FuncDef) and _is_public(sub.name):
                    if ast.get_docstring(sub) is None:
                        missing.append(
                            f"{path}:{sub.lineno}: method "
                            f"`{node.name}.{sub.name}` undocumented"
                        )
    return missing


def main(argv: list[str]) -> int:
    """Lint every .py file under the given roots; exit 1 on any miss."""
    roots = argv or DEFAULT_ROOTS
    missing: list[str] = []
    n_files = 0
    for root in roots:
        root_path = pathlib.Path(root)
        if not root_path.is_dir():
            print(f"docs-check: root `{root}` does not exist")
            return 1
        n_root = 0
        for path in sorted(root_path.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            n_root += 1
            missing.extend(check_module(path))
        if n_root == 0:
            print(f"docs-check: root `{root}` contains no Python modules")
            return 1
        n_files += n_root
    if missing:
        print(f"docs-check: {len(missing)} public symbol(s) lack docstrings:")
        for line in missing:
            print(f"  {line}")
        return 1
    print(f"docs-check: OK ({n_files} modules, all public symbols documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
