"""Pre-warm the autotuning cache for the shapes in ``repro.configs``
(DESIGN.md §11).

Steady-state serving/training pays zero tuning overhead when the
persistent cache (``REPRO_TUNE_CACHE``, default
``~/.cache/repro/tune.json``) already holds a measured winner for every
plan key the model will hit.  This CLI walks the architecture registry
and tunes, per config:

* the split-heads / merge-heads rearrangement family ((B, S, H, hd) and
  its inverse — the hottest permutes in the codebase, DESIGN.md §3/§7);
* the MoE dispatch + combine index plans at the config's expert count,
  fan-in and capacity (§4), for MoE architectures;
* a ``repeat(k)`` Jacobi stencil program on the requested grid (§9) —
  stencils are workload-shaped rather than config-shaped, so the grid is
  a flag, not a registry lookup.

Usage::

    PYTHONPATH=src python -m repro.tune                    # all archs
    PYTHONPATH=src python -m repro.tune --arch qwen2-7b --batch 8 --seq 2048
    PYTHONPATH=src python -m repro.tune --mode cost        # deterministic
    PYTHONPATH=src python -m repro.tune --list             # show the cache

``--mode auto`` (default) measures on TPU and cost-scores elsewhere —
exactly what a tuned planner does at run time, so the warmed winners are
the winners serving will reuse.
"""

from __future__ import annotations

import argparse
import json
import os


def _warm_config(name: str, batch: int, seq: int) -> list[str]:
    """Tune every plan key one architecture exercises; returns report lines."""
    from repro import configs
    from repro.core.index_plan import plan_index_op
    from repro.core.plan import plan_rearrange

    cfg = configs.get_config(name)
    dt = cfg.np_dtype
    hd = cfg.head_dim_resolved
    lines = []

    split = (batch, seq, cfg.n_heads, hd)
    merge = (batch, cfg.n_heads, seq, hd)
    for tag, shape in (("split_heads", split), ("merge_heads", merge)):
        plan = plan_rearrange(shape, dt, (0, 2, 1, 3), tuned=True)
        lines.append(
            f"{name}: {tag} {shape} -> tiles=({plan.block_r},{plan.block_c}) "
            f"[{plan.mode}]"
        )

    # the affine family (DESIGN.md §14): the seeded epoch shuffle over the
    # config's token stream and the bit-reversal layout over the head dim —
    # warming these covers the reorder_affine route the new ops dispatch to
    from repro.core import affine
    from repro.core.plan import plan_affine

    t = batch * seq
    shuf = affine.shuffle_map(t, payload=(cfg.d_model,), seed=0)
    plan = plan_affine(shuf, dt, tuned=True)
    lines.append(
        f"{name}: shuffle ({t}, {cfg.d_model}) -> "
        f"tiles=({plan.block_r},{plan.block_c}) "
        f"[{plan.mode}/{plan.plan_source}]"
    )
    try:
        rev = affine.bit_reversal_map((t, hd), axis=1)
    except ValueError:
        pass  # non-power-of-two head dim: the op has no affine lowering
    else:
        plan = plan_affine(rev, dt, tuned=True)
        lines.append(
            f"{name}: bit_reversal ({t}, {hd}) -> "
            f"tiles=({plan.block_r},{plan.block_c}) "
            f"[{plan.mode}/{plan.plan_source}]"
        )

    if cfg.moe is not None:
        from repro.models.moe import default_capacity

        t = batch * seq
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        cap = default_capacity(cfg, t)
        disp = plan_index_op(
            (t, cfg.d_model), dt, e * cap, "gather", masked=True, tuned=True
        )
        comb = plan_index_op(
            (e * cap, cfg.d_model), dt, t, "gather_combine",
            masked=True, top_k=k, tuned=True,
        )
        lines.append(f"{name}: moe dispatch blocks={disp.grid}x{disp.block_rows}")
        lines.append(f"{name}: moe combine  blocks={comb.grid}x{comb.block_rows}")
    return lines


def _warm_stencil(grid: int, sweeps: int) -> list[str]:
    """Tune the reference Jacobi program on an NxN grid."""
    import jax.numpy as jnp

    from repro.core import stencil as st

    jacobi = st.Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)
    plan = jacobi.repeat(sweeps).compile((grid, grid), jnp.float32, tuned=True)
    return [
        f"stencil: jacobi repeat({sweeps}) {grid}x{grid} -> "
        f"panel={plan.block_rows} [{plan.mode}]"
    ]


def main(argv: list[str] | None = None) -> int:
    """Entry point for ``python -m repro.tune``."""
    from repro import configs

    ap = argparse.ArgumentParser(
        prog="repro.tune", description="pre-warm the autotuning cache"
    )
    ap.add_argument("--arch", action="append", default=None,
                    help="architecture id (repeatable; default: all)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--grid", type=int, default=2048,
                    help="stencil grid side (0 skips the stencil warm)")
    ap.add_argument("--sweeps", type=int, default=8)
    ap.add_argument("--mode", choices=("auto", "measure", "cost"), default="auto",
                    help="selection backend (auto = measure on TPU, cost elsewhere)")
    ap.add_argument("--cache", default=None, help="override REPRO_TUNE_CACHE")
    ap.add_argument("--list", action="store_true",
                    help="print the cache contents and exit")
    args = ap.parse_args(argv)

    if args.cache:
        os.environ["REPRO_TUNE_CACHE"] = args.cache
    os.environ["REPRO_TUNE"] = {"auto": "on"}.get(args.mode, args.mode)

    from repro.core import tune as tune_core

    if args.list:
        doc = tune_core.load_cache()
        print(json.dumps(doc, indent=1, sort_keys=True))
        return 0

    names = args.arch or list(configs.ARCH_IDS)
    for name in names:
        for line in _warm_config(name, args.batch, args.seq):
            print(line)
    if args.grid:
        for line in _warm_stencil(args.grid, args.sweeps):
            print(line)

    doc = tune_core.load_cache()
    mode = tune_core.resolve_mode()
    print(
        f"# mode={mode}; cache {tune_core.cache_path()} now holds "
        f"{len(doc['entries'])} entries"
        + ("" if mode == "measure" else
           " (cost mode is deterministic and not persisted)")
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
