"""Generic N-D reorder kernel (paper §III-B "Reorder Kernel"), TPU-native.

The paper's canonicalization — *every valid reorder reduces to batched 2-D
data movement in the plane of the fastest-changing input dim and the
fastest-changing output dim* — is kept intact.  What changes on TPU:

* CUDA stores the stride tables in **constant memory**; every thread reads
  them to compute its source address.  On TPU we go one better: block
  indices are computed *arithmetically in the scalar core* inside the
  BlockSpec ``index_map`` (mixed-radix decomposition of the linearized
  batch grid index, with radices baked in as compile-time constants).
  Zero memory traffic for metadata, and no 5-dim performance cliff — the
  paper's Table 2 shows 43 GB/s at 5-D because of metadata-lookup overhead;
  our index arithmetic is free relative to the DMAs it schedules.
* Exactly **two axes are blocked**: the input-fastest axis (lane dim of the
  load tile) and the axis that becomes output-fastest (lane dim of the
  store tile).  All other axes are batch.  Both DMAs therefore move full
  lane-aligned tiles — coalesced-on-both-sides, per the paper.
* If the permutation *preserves* the fastest axis ("copy mode"), the kernel
  degenerates to a blocked gather of contiguous rows — the paper's N-to-M
  case with preserved dim-0.

``permute_nd`` is the full-array form; ``reorder_window`` is the windowed
N->M form (paper §III-B), sharing the same grid builder with the (static)
window base folded into the input index map (DESIGN.md §6).

``perm`` uses numpy convention: ``out axis j  <-  in axis perm[j]``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (
    cdiv,
    force_interpret,
    plan_copy_tiles,
    plan_transpose_tiles,
    sublanes,
)


def _permute_kernel(perm, x_ref, o_ref):
    o_ref[...] = jnp.transpose(x_ref[...], perm)


def _dim_semantics(n: int):
    try:
        return pltpu.CompilerParams(dimension_semantics=(pltpu.ARBITRARY,) * n)
    except Exception:  # pragma: no cover
        return None


def _movement_axes(perm: tuple[int, ...]) -> tuple[int | None, int, bool]:
    """The two blocked axes of the movement plane: (r_in, c_in, transpose?).

    r_in is None at rank 1 (no second axis to block — a pure lane copy)."""
    N = len(perm)
    c_in = N - 1
    transpose_mode = perm[-1] != c_in
    if N < 2:
        return None, c_in, False
    r_in = perm[-1] if transpose_mode else perm[-2]
    return r_in, c_in, transpose_mode


def _align_block(block: int, offset: int) -> int:
    """Largest block <= ``block`` (by halving) that divides ``offset``, so a
    window base can ride in the index map as a whole number of blocks."""
    while offset % block:
        block = max(1, block // 2)
    return block


def _reorder_call(
    x: jax.Array,
    perm: tuple[int, ...],
    base: tuple[int, ...],
    sizes: tuple[int, ...],
    br: int,
    bc: int,
    r_in: int | None,
    c_in: int,
    grid_order: str,
    interpret: bool,
) -> jax.Array:
    """Shared grid builder: ``transpose(x[base : base+sizes], perm)`` as one
    pallas_call.  Batch axes use unit blocks (any base offset is exact); the
    two blocked plane axes must have block-aligned bases (see callers)."""
    N = x.ndim
    W = sizes
    out_shape = tuple(W[p] for p in perm)

    blocks = [1] * N
    blocks[c_in] = bc
    if r_in is not None:
        blocks[r_in] = br
    nblocks = [cdiv(W[k], blocks[k]) for k in range(N)]
    offs = [base[k] // blocks[k] for k in range(N)]  # exact: blocks aligned

    plane = {c_in} if r_in is None else {r_in, c_in}
    if grid_order == "out":
        batch_in_axes = [p for p in perm if p not in plane]
    elif grid_order == "in":
        batch_in_axes = [k for k in range(N) if k not in plane]
    else:
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    batch_radix = [nblocks[a] for a in batch_in_axes]
    G = math.prod(batch_radix) if batch_radix else 1

    # mixed-radix weights: coordinate of batch axis a = (g // w[a]) % radix[a]
    weights: dict[int, int] = {}
    w = 1
    for a, r in zip(reversed(batch_in_axes), reversed(batch_radix)):
        weights[a] = w
        w *= r

    def win_coords(g, i, j):
        coords = []
        for k in range(N):
            if k == r_in:
                coords.append(i)
            elif k == c_in:
                coords.append(j)
            else:
                coords.append(lax.rem(g // weights[k], nblocks[k]))
        return coords

    def in_map(g, i, j):
        return tuple(c + offs[k] for k, c in enumerate(win_coords(g, i, j)))

    def out_map(g, i, j):
        c = win_coords(g, i, j)
        return tuple(c[p] for p in perm)

    in_block = tuple(blocks)
    out_block = tuple(blocks[p] for p in perm)
    grid_r = nblocks[r_in] if r_in is not None else 1

    params = _dim_semantics(3)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        functools.partial(_permute_kernel, perm),
        grid=(G, grid_r, nblocks[c_in]),
        in_specs=[pl.BlockSpec(in_block, in_map)],
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        interpret=interpret,
        **kwargs,
    )(x)


def _plan_blocks(
    perm: tuple[int, ...], sizes: tuple[int, ...], dtype
) -> tuple[int, int, int | None, int, bool]:
    """Tile the movement plane of ``perm`` over (window) ``sizes``."""
    r_in, c_in, transpose_mode = _movement_axes(perm)
    R = sizes[r_in] if r_in is not None else 1
    C = sizes[c_in]
    if transpose_mode:
        plan = plan_transpose_tiles(R, C, dtype)
    else:
        plan = plan_copy_tiles(R, C, dtype)
    return plan.block_r, plan.block_c, r_in, c_in, transpose_mode


@functools.partial(
    jax.jit,
    static_argnames=("perm", "block_r", "block_c", "grid_order", "interpret"),
)
def permute_nd(
    x: jax.Array,
    perm: tuple[int, ...],
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    grid_order: str = "out",
    interpret: bool | None = None,
) -> jax.Array:
    """General N-D permute: ``out = jnp.transpose(x, perm)`` as a tiled
    Pallas data-movement kernel.

    grid_order: 'out' walks batch blocks in output-linear order (stores are
    sequential in HBM), 'in' walks in input-linear order (loads sequential).
    This is the TPU analogue of the paper's block-scheduling policies.
    """
    N = x.ndim
    perm = tuple(int(p) for p in perm)
    if sorted(perm) != list(range(N)):
        raise ValueError(f"bad perm {perm} for rank {N}")
    if N == 0 or perm == tuple(range(N)):
        # identity: fall through to a plain copy (still a kernel-shaped op)
        return x + jnp.zeros((), x.dtype)

    pr, pc, r_in, c_in, _ = _plan_blocks(perm, x.shape, x.dtype)
    br = min(block_r or pr, x.shape[r_in]) if r_in is not None else 1
    bc = min(block_c or pc, x.shape[c_in])
    interpret = force_interpret() if interpret is None else interpret
    return _reorder_call(
        x, perm, (0,) * N, x.shape, br, bc, r_in, c_in, grid_order, interpret
    )


@functools.partial(
    jax.jit, static_argnames=("perm", "base", "sizes", "grid_order", "interpret")
)
def reorder_window(
    x: jax.Array,
    perm: tuple[int, ...],
    base: tuple[int, ...],
    sizes: tuple[int, ...],
    *,
    grid_order: str = "out",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused windowed N->M reorder (paper §III-B): one pallas_call computing
    ``transpose(x[base : base + sizes], perm)``.

    The window slice is *not* materialized — the static base offsets are
    folded into the input BlockSpec ``index_map`` (the TPU analogue of the
    paper's constant-memory metadata), so the windowed reorder is a single
    pass over HBM instead of slice-then-permute.  Blocked plane axes shrink
    their block (by halving) until the base offset is block-aligned; batch
    axes use unit blocks so any offset is exact.  A base so misaligned that
    the plane blocks would degrade below the sublane floor raises
    ValueError — dispatch then falls back to the two-pass form rather than
    issuing element-granular DMAs.
    """
    N = x.ndim
    perm = tuple(int(p) for p in perm)
    if sorted(perm) != list(range(N)):
        raise ValueError(f"bad perm {perm} for rank {N}")
    if len(base) != N or len(sizes) != N:
        raise ValueError(f"base/sizes must have rank {N}")
    for k in range(N):
        if not (0 <= base[k] and base[k] + sizes[k] <= x.shape[k]):
            raise ValueError(
                f"window [{base[k]}, {base[k]}+{sizes[k]}) exceeds axis {k} "
                f"of shape {x.shape}"
            )
    W = tuple(int(s) for s in sizes)

    pr, pc, r_in, c_in, _ = _plan_blocks(perm, W, x.dtype)
    br = _align_block(min(pr, W[r_in]), base[r_in]) if r_in is not None else 1
    bc = _align_block(min(pc, W[c_in]), base[c_in])
    # quality gate: misaligned bases shrink plane blocks; below the dtype's
    # sublane floor the fused pass would be slower than slice-then-permute
    sl = sublanes(x.dtype)
    floor_r = min(sl, W[r_in]) if r_in is not None else 1
    floor_c = min(sl, W[c_in])
    if (r_in is not None and br < floor_r) or bc < floor_c:
        raise ValueError(
            f"window base {base} too misaligned for fused blocks "
            f"({br}x{bc} < {floor_r}x{floor_c})"
        )
    interpret = force_interpret() if interpret is None else interpret
    return _reorder_call(
        x, perm, tuple(int(b) for b in base), W, br, bc, r_in, c_in,
        grid_order, interpret,
    )
