"""Architecture registry: the 10 assigned architectures as selectable
configs (``--arch <id>``), plus reduced smoke variants."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPE_CELLS, ModelConfig, ShapeCell, smoke_variant

_MODULES = {
    "qwen2-7b": "qwen2_7b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "minitron-8b": "minitron_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "xlstm-125m": "xlstm_125m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama-3.2-vision-90b": "llama3p2_vision_90b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return smoke_variant(get_config(name[: -len("-smoke")]))
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cells_for(cfg: ModelConfig) -> list[ShapeCell]:
    """The shape cells this arch runs (spec-mandated skips applied)."""
    cells = [SHAPE_CELLS["train_4k"], SHAPE_CELLS["prefill_32k"], SHAPE_CELLS["decode_32k"]]
    if cfg.subquadratic:
        cells.append(SHAPE_CELLS["long_500k"])
    return cells
