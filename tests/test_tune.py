"""Autotuner tests (DESIGN.md §11, core/tune.py).

The contract under test, per the tuner's design:

* ``REPRO_TUNE=off`` (the CI default) — every plan from all four engines
  is the heuristic one: identical objects to the untuned lru path, and
  the tuner's selection machinery is never consulted.
* tuning on — tuned and untuned plans may differ in tiles / grid order /
  engine choice, but executing them is bit-identical for fp32 and bf16,
  including ragged and zero-size shapes.
* the persistent cache survives hostile conditions: corrupt, stale, and
  other-version files are ignored and rebuilt, concurrent writers cannot
  tear the file, a recorded winner short-circuits re-timing.
* tuned plans get the same lru identity guarantees as untuned plans.
* the benchmark-regression gate (tools/check_bench.py) passes on the
  committed BENCH_*.json and exits nonzero on an injected regression.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dist_plan, index_plan, plan, stencil, tune
from repro.kernels import ops

REPO = pathlib.Path(__file__).resolve().parent.parent


def _clear_tuned_caches():
    plan._plan_tuned_cached.cache_clear()
    index_plan._plan_tuned_cached.cache_clear()
    stencil._plan_tuned_cached.cache_clear()
    dist_plan._plan_rearrange_tuned.cache_clear()
    dist_plan._plan_stencil_tuned.cache_clear()


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Point the persistent cache at a throwaway path and clear the tuned
    lru caches (they may hold plans tuned against another cache file)."""
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    _clear_tuned_caches()
    yield path
    _clear_tuned_caches()


JACOBI = stencil.Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)


# ---------------------------------------------------------------------------
# REPRO_TUNE=off: bit-identical heuristic plans, tuner never consulted
# ---------------------------------------------------------------------------


class TestOffBitIdentity:
    SHAPES = [
        ((8, 64, 4, 16), (0, 2, 1, 3)),   # split-heads (vec transpose)
        ((16, 8, 32), (2, 1, 0)),          # generic reorder
        ((5, 7, 3), (1, 0, 2)),            # ragged
        ((0, 4, 8), (2, 0, 1)),            # zero-size
    ]

    def test_rearrange_off_is_untuned_object(self, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "off")
        for shape, perm in self.SHAPES:
            for dt in (jnp.float32, jnp.bfloat16):
                p_env = plan.plan_rearrange(shape, dt, perm)
                p_explicit = plan.plan_rearrange(shape, dt, perm, tuned=False)
                p_unset = plan._plan_cached(
                    shape, jnp.dtype(dt).name, perm, "out"
                )
                assert p_env is p_explicit is p_unset

    def test_off_never_consults_selection(self, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "off")

        def boom(*a, **k):  # pragma: no cover - would fail the test
            raise AssertionError("tuner consulted under REPRO_TUNE=off")

        monkeypatch.setattr(tune, "select", boom)
        plan._plan_cached.cache_clear()
        plan.plan_rearrange((4, 8, 16), jnp.float32, (1, 0, 2))
        index_plan.plan_index_op((32, 16), jnp.float32, 16, "gather")
        stencil.plan_stencil((32, 64), jnp.float32, JACOBI.repeat(2).stages)
        dist_plan.plan_dist_rearrange(
            (("x", 4),), ("x", None), (None, "x"), (8, 16), jnp.float32, (1, 0)
        )

    def test_index_off_is_untuned_object(self, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "off")
        for args in [
            ((64, 32), 48, "gather", False, 1),
            ((64, 32), 48, "gather", True, 1),
            ((16, 8), 32, "scatter", True, 1),
            ((32, 16), 24, "gather_combine", True, 2),
            ((0, 16), 8, "gather", True, 1),
            ((16, 16), 0, "gather", False, 1),
        ]:
            src, n_out, sem, masked, k = args
            a = index_plan.plan_index_op(
                src, jnp.bfloat16, n_out, sem, masked=masked, top_k=k
            )
            b = index_plan.plan_index_op(
                src, jnp.bfloat16, n_out, sem, masked=masked, top_k=k, tuned=False
            )
            assert a is b

    def test_stencil_off_is_untuned_object(self, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "off")
        prog = JACOBI.repeat(3)
        a = prog.compile((64, 96), jnp.float32)
        b = prog.compile((64, 96), jnp.float32, tuned=False)
        assert a is b

    def test_dist_off_is_untuned_object(self, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "off")
        mk = (("x", 8),)
        a = dist_plan.plan_dist_rearrange(
            mk, ("x", None, None), (None, None, "x"), (64, 128, 256),
            jnp.float32, (1, 0, 2),
        )
        b = dist_plan.plan_dist_rearrange(
            mk, ("x", None, None), (None, None, "x"), (64, 128, 256),
            jnp.float32, (1, 0, 2), tuned=False,
        )
        assert a is b


# ---------------------------------------------------------------------------
# tuned == untuned results, bit-identical (fp32 + bf16, ragged, zero-size)
# ---------------------------------------------------------------------------


def _sample(shape, dt, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dt)


class TestTunedEquivalence:
    @pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "shape,perm",
        [
            ((4, 64, 4, 16), (0, 2, 1, 3)),  # vec-transpose route
            ((16, 8, 32), (2, 1, 0)),        # reorder route
            ((8, 32, 16), (0, 2, 1)),        # scalar transpose route
            ((5, 7, 3), (1, 0, 2)),          # ragged
            ((0, 4, 8), (2, 0, 1)),          # zero-size
        ],
    )
    def test_rearrange(self, pallas_interpret, tune_cache, monkeypatch, shape, perm, dt):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        x = _sample(shape, dt)
        p0 = plan.plan_rearrange(shape, dt, perm, tuned=False)
        p1 = plan.plan_rearrange(shape, dt, perm, tuned=True)
        y0 = ops.apply_plan(x, p0)
        y1 = ops.apply_plan(x, p1)
        assert y0.dtype == y1.dtype and y0.shape == y1.shape
        assert bool(jnp.all(y0 == y1))

    @pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "n_src,cols,n_out,sem,masked,k",
        [
            (64, 32, 48, "gather", False, 1),   # rowwise is a candidate here
            (64, 32, 48, "gather", True, 1),
            (24, 16, 40, "scatter", True, 1),   # capacity scatter
            (40, 16, 24, "gather_combine", True, 2),
            (7, 5, 11, "gather", True, 1),      # ragged
            (16, 16, 0, "gather", False, 1),    # zero-size
        ],
    )
    def test_index(self, pallas_interpret, tune_cache, monkeypatch,
                   n_src, cols, n_out, sem, masked, k, dt):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        x = _sample((n_src, cols), dt)
        rng = np.random.default_rng(1)
        p0 = index_plan.plan_index_op(
            (n_src, cols), dt, n_out, sem, masked=masked, top_k=k, tuned=False
        )
        p1 = index_plan.plan_index_op(
            (n_src, cols), dt, n_out, sem, masked=masked, top_k=k, tuned=True
        )
        if sem == "gather_combine":
            idx = jnp.asarray(
                rng.integers(-1 if masked else 0, n_src, (n_out, k)), jnp.int32
            )
            gates = jnp.asarray(rng.random((n_out, k)), jnp.float32)
            y0 = ops.apply_index_plan(x, idx, p0, gates=gates)
            y1 = ops.apply_index_plan(x, idx, p1, gates=gates)
        elif sem == "scatter":
            idx = jnp.asarray(
                rng.permutation(n_out)[:n_src], jnp.int32
            )
            y0 = ops.apply_index_plan(x, idx, p0)
            y1 = ops.apply_index_plan(x, idx, p1)
        else:
            lo = -2 if masked else 0
            idx = jnp.asarray(
                rng.integers(lo, max(n_src, 1), (n_out,)), jnp.int32
            )
            y0 = ops.apply_index_plan(x, idx, p0)
            y1 = ops.apply_index_plan(x, idx, p1)
        assert bool(jnp.all(y0 == y1))

    @pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(64, 96), (50, 40)])
    @pytest.mark.parametrize("boundary", ["zero", "reflect"])
    def test_stencil(self, pallas_interpret, tune_cache, monkeypatch,
                     shape, boundary, dt):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        prog = JACOBI.repeat(3)
        x = _sample(shape, dt)
        p0 = prog.compile(shape, dt, boundary=boundary, tuned=False)
        p1 = prog.compile(shape, dt, boundary=boundary, tuned=True)
        y0 = ops.stencil_program(
            x, p0.stages_exec, boundary=boundary,
            block_rows=p0.block_rows or None, fused=p0.mode == "fused",
        )
        y1 = ops.stencil_program(
            x, p1.stages_exec, boundary=boundary,
            block_rows=p1.block_rows or None, fused=p1.mode == "fused",
        )
        assert bool(jnp.all(y0 == y1))

    def test_tuned_plan_still_one_pallas_call(self, pallas_interpret,
                                              tune_cache, monkeypatch):
        """Tuning changes which plan is cached, never the lowering shape:
        a tuned rearrangement still executes as exactly ONE pallas_call
        (the §3 contract), and a tuned stencil program stays one fused
        kernel."""
        monkeypatch.setenv("REPRO_TUNE", "cost")
        shape, perm = (4, 64, 4, 16), (0, 2, 1, 3)
        x = _sample(shape, jnp.float32)
        p1 = plan.plan_rearrange(shape, jnp.float32, perm, tuned=True)
        jaxpr = str(jax.make_jaxpr(lambda a: ops.apply_plan(a, p1))(x))
        assert jaxpr.count("pallas_call[") == 1
        g = _sample((64, 96), jnp.float32)
        sp = JACOBI.repeat(3).compile((64, 96), jnp.float32, tuned=True)
        assert sp.mode == "fused"
        jaxpr = str(jax.make_jaxpr(
            lambda a: ops.stencil_program(
                a, sp.stages_exec, boundary="zero",
                block_rows=sp.block_rows or None, fused=True,
            )
        )(g))
        assert jaxpr.count("pallas_call[") == 1

    def test_rowwise_engine_candidate_bit_identical(self, pallas_interpret):
        """The engine-choice candidate (seed rowwise kernel vs blocked
        kernel) is exact — the precondition for the tuner offering it."""
        x = _sample((32, 16), jnp.float32)
        idx = jnp.asarray(
            np.random.default_rng(2).integers(0, 32, (20,)), jnp.int32
        )
        p_row = index_plan._build_plan(
            32, 16, "float32", 20, "gather", False, 1, engine="rowwise"
        )
        p_blk = index_plan.plan_index_op((32, 16), jnp.float32, 20, "gather")
        assert p_row.mode == "rowwise" and p_row.kernel == "gather_rows"
        assert bool(jnp.all(
            ops.apply_index_plan(x, idx, p_row)
            == ops.apply_index_plan(x, idx, p_blk)
        ))

    def test_dist_tuned_strategy_stays_feasible(self, tune_cache, monkeypatch):
        """Dist tuning only moves between strategies the executors run and
        the §10 suite proves bit-identical (exec-level identity is covered
        on the 8-device mesh in test_dist_plan.py)."""
        monkeypatch.setenv("REPRO_TUNE", "cost")
        mk = (("x", 8),)
        p0 = dist_plan.plan_dist_rearrange(
            mk, ("x", None, None), (None, None, "x"), (64, 128, 256),
            jnp.float32, (1, 0, 2), tuned=False,
        )
        p1 = dist_plan.plan_dist_rearrange(
            mk, ("x", None, None), (None, None, "x"), (64, 128, 256),
            jnp.float32, (1, 0, 2), tuned=True,
        )
        assert p1.strategy in ("all_to_all", "replicate")
        assert (p1.in_spec, p1.out_spec) == (p0.in_spec, p0.out_spec)
        s0 = dist_plan.plan_dist_stencil(
            mk, "x", (64, 128), jnp.float32, JACOBI.repeat(4).stages, "zero",
            tuned=False,
        )
        s1 = dist_plan.plan_dist_stencil(
            mk, "x", (64, 128), jnp.float32, JACOBI.repeat(4).stages, "zero",
            tuned=True,
        )
        assert s0.strategy == "halo"
        assert s1.strategy in ("halo", "replicate")

    def test_measured_mode_equivalence(self, pallas_interpret, tune_cache,
                                       monkeypatch):
        """REPRO_TUNE=measure actually times candidates (tiny shapes) and
        the measured winner still computes identical bytes."""
        monkeypatch.setenv("REPRO_TUNE", "measure")
        shape, perm = (2, 16, 4, 8), (0, 2, 1, 3)
        x = _sample(shape, jnp.float32)
        p0 = plan.plan_rearrange(shape, jnp.float32, perm, tuned=False)
        p1 = plan.plan_rearrange(shape, jnp.float32, perm, tuned=True)
        assert bool(jnp.all(ops.apply_plan(x, p0) == ops.apply_plan(x, p1)))
        assert tune_cache.exists()  # the winner was persisted


# ---------------------------------------------------------------------------
# selection machinery
# ---------------------------------------------------------------------------


def _cands(costs):
    return [
        tune.Candidate(label=f"c{i}", params=(("i", i),), cost_s=c)
        for i, c in enumerate(costs)
    ]


class TestSelect:
    def test_cost_mode_picks_min_first_wins_ties(self):
        cands = _cands([2.0, 1.0, 1.0])
        got = tune.select("t", "k", cands, None, mode="cost", persist=False)
        assert got.label == "c1"
        cands = _cands([1.0, 1.0, 2.0])
        got = tune.select("t", "k", cands, None, mode="cost", persist=False)
        assert got.label == "c0"  # heuristic wins the tie

    def test_no_runner_falls_back_to_cost_in_measure_mode(self):
        cands = _cands([3.0, 1.0])
        got = tune.select("t", "k", cands, None, mode="measure", persist=False)
        assert got.label == "c1"

    def test_single_candidate_short_circuits(self):
        cands = _cands([1.0])
        assert tune.select("t", "k", cands, None, mode="measure") is cands[0]

    def test_measure_skips_raising_candidates(self, tune_cache):
        cands = _cands([1.0, 2.0, 3.0])

        def factory(c):
            if c.label != "c2":
                raise ValueError("illegal candidate")
            return lambda: 0

        got = tune.select("t", "k1", cands, factory, mode="measure")
        assert got.label == "c2"

    def test_measure_all_fail_keeps_heuristic_without_persisting(self, tune_cache):
        cands = _cands([1.0, 2.0])

        def factory(c):
            def run():
                raise ValueError("boom")
            return run

        got = tune.select("t", "k2", cands, factory, mode="measure")
        assert got.label == "c0"
        # a transient all-fail must NOT record a winner (it would
        # short-circuit re-tuning forever, and inf is not strict JSON)
        assert tune.lookup("t|k2") is None


# ---------------------------------------------------------------------------
# the persistent cache: hostile files, atomicity, short-circuit
# ---------------------------------------------------------------------------


class TestCacheRobustness:
    def test_missing_file_is_empty(self, tune_cache):
        assert tune.load_cache()["entries"] == {}

    def test_corrupt_file_ignored_and_rebuilt(self, tune_cache):
        tune_cache.write_text("{not json!!")
        assert tune.load_cache()["entries"] == {}
        tune.store_entry("k", {"label": "x"})
        doc = json.loads(tune_cache.read_text())  # valid again
        assert doc["entries"]["k"]["label"] == "x"

    def test_other_version_and_backend_ignored(self, tune_cache):
        good = tune.load_cache()
        for field, bad in (("schema", 999), ("jax", "0.0.1"), ("backend", "tpu9")):
            doc = {**good, field: bad, "entries": {"k": {"label": "stale"}}}
            tune_cache.write_text(json.dumps(doc))
            assert tune.load_cache()["entries"] == {}, field

    def test_lookup_roundtrip(self, tune_cache):
        tune.store_entry("a|b", {"label": "c1", "us": 1.0})
        assert tune.lookup("a|b")["label"] == "c1"
        assert tune.lookup("missing") is None

    def test_recorded_winner_short_circuits_timing(self, tune_cache, monkeypatch):
        cands = _cands([1.0, 2.0])
        tune.store_entry("t|k", {"label": "c1"})

        def boom(*a, **k):  # pragma: no cover - would fail the test
            raise AssertionError("re-timed despite a recorded winner")

        monkeypatch.setattr(tune, "time_candidates", boom)
        got = tune.select("t", "k", cands, lambda c: (lambda: 0), mode="measure")
        assert got.label == "c1"

    def test_unknown_recorded_winner_retunes(self, tune_cache):
        cands = _cands([1.0, 2.0])
        tune.store_entry("t|k", {"label": "gone-since-refactor"})
        got = tune.select("t", "k", cands, lambda c: (lambda: 0), mode="measure")
        assert got.label in ("c0", "c1")
        assert tune.lookup("t|k")["label"] == got.label  # rewritten

    def test_concurrent_writers_never_tear(self, tune_cache):
        def writer(i):
            for j in range(10):
                tune.store_entry(f"k{i}-{j}", {"label": f"w{i}", "us": j})

        threads = [threading.Thread(target=writer, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        doc = json.loads(tune_cache.read_text())  # parseable => not torn
        assert doc["schema"] == tune.SCHEMA_VERSION
        assert doc["entries"]  # last writer's merge survived intact
        for rec in doc["entries"].values():
            assert "label" in rec

    def test_unwritable_cache_is_ignored(self, monkeypatch):
        monkeypatch.setenv(
            "REPRO_TUNE_CACHE", "/proc/definitely/not/writable/tune.json"
        )
        tune.store_entry("k", {"label": "x"})  # must not raise


# ---------------------------------------------------------------------------
# lru identity for tuned plans
# ---------------------------------------------------------------------------


class TestTunedIdentity:
    def test_rearrange_tuned_identity(self, tune_cache, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        a = plan.plan_rearrange((8, 64, 4, 16), jnp.float32, (0, 2, 1, 3), tuned=True)
        b = plan.plan_rearrange((8, 64, 4, 16), jnp.float32, (0, 2, 1, 3), tuned=True)
        assert a is b

    def test_index_tuned_identity(self, tune_cache, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        a = index_plan.plan_index_op((64, 32), jnp.float32, 48, "gather", tuned=True)
        b = index_plan.plan_index_op((64, 32), jnp.float32, 48, "gather", tuned=True)
        assert a is b

    def test_stencil_tuned_identity(self, tune_cache, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        prog = JACOBI.repeat(2)
        assert prog.compile((64, 96), jnp.float32, tuned=True) is prog.compile(
            (64, 96), jnp.float32, tuned=True
        )

    def test_env_on_routes_default_calls_through_tuner(self, tune_cache, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE", "cost")
        before = plan.tuned_plan_cache_info().misses
        p = plan.plan_rearrange((4, 32, 2, 8), jnp.float32, (0, 2, 1, 3))
        after = plan.tuned_plan_cache_info().misses
        assert after == before + 1
        # and the tuned default call caches to the same object
        assert plan.plan_rearrange((4, 32, 2, 8), jnp.float32, (0, 2, 1, 3)) is p


# ---------------------------------------------------------------------------
# the benchmark-regression gate
# ---------------------------------------------------------------------------


def _run_gate(root: pathlib.Path) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py"),
         "--no-smoke", "--root", str(root)],
        capture_output=True, text=True, timeout=120,
    )


class TestBenchCheckGate:
    @pytest.fixture
    def bench_dir(self, tmp_path):
        for f in ("BENCH_rearrange.json", "BENCH_stencil.json",
                  "BENCH_moe.json", "BENCH_dist.json", "BENCH_serve.json",
                  "BENCH_train.json"):
            shutil.copy(REPO / f, tmp_path / f)
        return tmp_path

    def test_committed_files_pass(self, bench_dir):
        r = _run_gate(bench_dir)
        assert r.returncode == 0, r.stdout + r.stderr

    def test_injected_regression_fails(self, bench_dir):
        p = bench_dir / "BENCH_moe.json"
        doc = json.loads(p.read_text())
        for row in doc["rows"]:
            if row["op"] == "moe_dispatch_sort_fused":
                row["gbps"] = 0.0001
        p.write_text(json.dumps(doc))
        r = _run_gate(bench_dir)
        assert r.returncode == 1
        assert "measured-path regression" in r.stdout

    def test_structure_break_fails(self, bench_dir):
        (bench_dir / "BENCH_stencil.json").write_text("{]")
        r = _run_gate(bench_dir)
        assert r.returncode == 1
        assert "unparseable" in r.stdout

    def test_missing_ratio_row_fails(self, bench_dir):
        p = bench_dir / "BENCH_dist.json"
        doc = json.loads(p.read_text())
        doc["rows"] = [r for r in doc["rows"]
                       if not r["op"].startswith("stencil_halo")]
        p.write_text(json.dumps(doc))
        r = _run_gate(bench_dir)
        assert r.returncode == 1


# ---------------------------------------------------------------------------
# the pre-warm CLI
# ---------------------------------------------------------------------------


class TestTuneCLI:
    def test_warm_and_list(self, tune_cache, monkeypatch, capsys):
        from repro import tune as tune_cli

        monkeypatch.setenv("REPRO_TUNE", "off")  # main() overwrites; restore after
        rc = tune_cli.main([
            "--arch", "qwen2-7b", "--batch", "2", "--seq", "32",
            "--grid", "64", "--mode", "cost",
        ])
        assert rc == 0
        out = capsys.readouterr().out
        assert "split_heads" in out and "stencil: jacobi" in out
        assert tune_cli.main(["--list"]) == 0
