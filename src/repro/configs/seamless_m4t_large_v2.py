"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf] — encoder-decoder backbone.

Per the assignment spec the modality frontend is a STUB: ``input_specs()``
provides precomputed audio-frame embeddings (B, n_frames, d_model); the
24L encoder is the transformer backbone over those frames, the 24L decoder
is a standard self+cross stack.  Sinusoidal positions, LayerNorm, GELU.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    pos_embed="sinusoidal",
    tie_embeddings=True,
    unit=("dec",),
    n_frontend_tokens=1024,  # stub: precomputed speech frames
    source="arXiv:2308.11596 (hf: facebook/seamless-m4t-v2-large)",
)
