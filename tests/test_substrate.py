"""Substrate tests: optimizer, checkpoint, data pipeline, elastic plan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticSource
from repro.optim import adamw
from repro.train.checkpoint import Checkpointer
from repro.train import elastic


def test_adamw_converges_quadratic():
    oc = adamw.OptConfig(lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, metrics = adamw.update(params, g, state, oc)
    assert float(loss(params)) < 1e-2
    assert int(state["step"]) == 200


def test_lr_schedule_shape():
    oc = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.lr_at(oc, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[1] == pytest.approx(0.5, abs=0.01)  # mid-warmup
    assert lrs[2] == pytest.approx(1.0, abs=0.01)  # peak
    assert lrs[3] < lrs[2]  # decaying
    assert lrs[4] == pytest.approx(0.1, abs=0.01)  # floor


def test_grad_clipping():
    oc = adamw.OptConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw.update(params, g, state, oc)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(tmp_path, keep_last=2, async_save=False)
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16), "d": [jnp.int32(7), jnp.zeros(3)]},
    }
    ckpt.save(10, tree)
    ckpt.save(20, tree)
    ckpt.save(30, tree)
    assert ckpt.all_steps() == [20, 30]  # pruned to keep_last
    skel = jax.tree.map(np.asarray, tree)
    restored = ckpt.restore(30, skel)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype


def test_checkpoint_atomicity(tmp_path):
    ckpt = Checkpointer(tmp_path, async_save=False)
    ckpt.save(1, {"x": jnp.ones(4)})
    # a crashed write leaves only a .tmp dir — must be invisible
    (tmp_path / "step_00000002.tmp").mkdir()
    assert ckpt.latest_step() == 1


def test_data_determinism_and_resume():
    dc = DataConfig(batch=4, seq=16, vocab=1000, seed=7)
    src = SyntheticSource(dc)
    b5 = src.batch_at(5)
    b5_again = src.batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])
    # labels are next-token shifted
    full = src.batch_at(3)
    assert full["tokens"].shape == (4, 16)
    # host sharding partitions the batch
    dc2 = DataConfig(batch=4, seq=16, vocab=1000, seed=7, n_hosts=2, host_id=1)
    half = SyntheticSource(dc2).batch_at(5)
    assert half["tokens"].shape == (2, 16)


def test_prefetcher_orders_steps():
    dc = DataConfig(batch=2, seq=8, vocab=100, seed=1)
    pf = Prefetcher(SyntheticSource(dc), start_step=10)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [10, 11, 12, 13]


def test_elastic_plan_mesh_shrinks_data_axis():
    # a 128-device slice losing 9 devices: model width preserved, data
    # shrinks to the largest multiple (stragglers evicted)
    shape, axes = elastic.plan_mesh_shape(119, model_width=16)
    assert shape == (7, 16) and axes == ("data", "model")
    shape, axes = elastic.plan_mesh_shape(512, model_width=16, pods=2)
    assert shape == (2, 16, 16)
    with pytest.raises(ValueError):
        elastic.plan_mesh_shape(8, model_width=16)
    assert elastic.rescale_batch(256, old_data=16, new_data=12) == 192


def test_trainer_accum_equivalence():
    """accum=2 over a doubled batch == accum=1 averaged gradients."""
    from repro import configs
    from repro.train.trainer import make_train_step

    cfg = configs.get_config("xlstm-125m-smoke")
    from repro.models import transformer as tf

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    oc = adamw.OptConfig(lr=1e-3)
    opt = adamw.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    step1 = make_train_step(cfg, oc, None, accum_steps=1)
    step2 = make_train_step(cfg, oc, None, accum_steps=2)
    p1, _, m1 = step1(params, opt, batch)
    p2, _, m2 = step2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-2
        )
