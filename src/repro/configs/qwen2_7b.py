"""Qwen2-7B [arXiv:2407.10671; hf] — dense GQA decoder with QKV bias."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    unit=("attn",),
    source="arXiv:2407.10671 (hf: Qwen/Qwen2-7B)",
)
