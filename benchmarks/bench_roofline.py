"""Roofline table from the dry-run records (deliverable (g)).

Reads runs/dryrun/single/*.json and prints the three terms per cell.
Run the dry-run first:  PYTHONPATH=src python -m repro.launch.dryrun
"""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path("runs/dryrun/single")


def run() -> list[str]:
    out = []
    if not DRYRUN.exists():
        return ["# no dry-run records yet (run repro.launch.dryrun)"]
    for f in sorted(DRYRUN.glob("*.json")):
        d = json.loads(f.read_text())
        cell = f"{d['arch']}--{d['cell']}"
        if d.get("status") == "skipped":
            out.append(f"{cell},skipped,{d.get('reason', '')[:60]}")
            continue
        if d.get("status") != "ok" or "roofline" not in d:
            out.append(f"{cell},{d.get('status')},{d.get('error', '')[:80]}")
            continue
        r = d["roofline"]
        out.append(
            f"{cell},{r['step_time_s']*1e6:.0f},"
            f"compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
            f"collective={r['collective_s']*1e3:.1f}ms bottleneck={r['bottleneck']} "
            f"mfu_bound={r['mfu_bound']:.3f} useful_ratio={r['useful_flops_ratio']:.2f}"
        )
    return out
