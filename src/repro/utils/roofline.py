"""Roofline math (TPU v5e constants) — see EXPERIMENTS.md §Roofline.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

cost_analysis() and the HLO text are per-device (post-SPMD) programs, so
the prompt's global formulation (global / (chips * bw)) reduces to these.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

PEAK_BF16_FLOPS = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

# fixed-cost terms for the tuner's candidate scoring (DESIGN.md §11).
# Rough v5e figures: one pallas_call dispatch, the per-grid-step pipeline
# bubble (DMA issue + semaphore wait that double buffering cannot hide at
# the panel boundary), and the ICI latency of launching one collective.
KERNEL_LAUNCH_S = 2e-6
GRID_STEP_S = 2e-7
COLLECTIVE_LAUNCH_S = 5e-6


def movement_cost_s(
    bytes_moved: float,
    grid_steps: int = 1,
    *,
    wire_bytes: float = 0.0,
    collectives: int = 0,
) -> float:
    """Cost-model score (seconds) for one movement candidate: HBM traffic
    at bandwidth plus the fixed per-kernel/per-grid-step overheads, plus
    the wire term for distributed candidates.  This is the deterministic
    fallback the autotuner (``core/tune.py``) ranks candidates with when
    measured timing is unavailable (``REPRO_TUNE=off``, interpret mode, or
    no runner) — unlike the pure ``bytes / bw`` roofline it separates
    candidates that move the same useful bytes with different padding
    waste or grid granularity."""
    return (
        bytes_moved / HBM_BW
        + KERNEL_LAUNCH_S
        + grid_steps * GRID_STEP_S
        + wire_bytes / ICI_BW
        + collectives * COLLECTIVE_LAUNCH_S
    )


@dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops_global: float
    n_chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_BF16_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of terms (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.n_chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * PEAK_BF16_FLOPS * self.n_chips
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            bottleneck=self.bottleneck,
            step_time_s=self.step_time_s,
            useful_flops_ratio=self.useful_flops_ratio,
            mfu_bound=self.mfu_bound,
        )
        return d


def model_flops(cfg, cell) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference, N = active params."""
    n = active_params(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    tokens = cell.global_batch  # one step
    return 2.0 * n * tokens


def active_params(cfg) -> float:
    """Parameter count, using ACTIVE experts only for MoE."""
    d, v, l = cfg.d_model, cfg.vocab, cfg.n_layers
    hd = cfg.head_dim_resolved
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    for unit, count in cfg.decoder_plan():
        for kind in unit:
            total += count * _block_params(cfg, kind, d, hd)
    if cfg.encoder_layers:
        total += cfg.encoder_layers * _block_params(cfg, "enc", d, hd)
    return float(total)


def _block_params(cfg, kind: str, d: int, hd: int) -> float:
    qkv = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    o = cfg.n_heads * hd * d
    def ffn(f, act=None):
        gated = (act or cfg.act) in ("swiglu", "geglu")
        return d * f * (3 if gated else 2)
    if kind in ("attn", "enc", "local"):
        return qkv + o + ffn(cfg.d_ff)
    if kind == "attn_dense":
        return qkv + o + ffn(cfg.d_ff_dense or cfg.d_ff)
    if kind == "attn_moe":
        mc = cfg.moe
        active = (mc.top_k + mc.n_shared) * ffn(mc.d_expert)
        return qkv + o + active + d * mc.n_experts
    if kind == "xattn":
        return d * cfg.n_heads * hd + d * 2 * cfg.n_kv_heads * hd + o + ffn(cfg.d_ff)
    if kind == "dec":
        cross = d * cfg.n_heads * hd + d * 2 * cfg.n_kv_heads * hd + o
        return qkv + o + cross + ffn(cfg.d_ff)
    if kind == "mlstm":
        return 3 * d * d + 2 * d * cfg.n_heads + 2 * d * d
    if kind == "slstm":
        return 4 * d * d + 4 * d * (d // cfg.n_heads) + d * d
    if kind == "rglru":
        return 4 * d * d + 4 * d + d * d + ffn(cfg.d_ff)
    raise ValueError(kind)
