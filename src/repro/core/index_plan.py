"""Index-set planner: block -> route -> cache (DESIGN.md §4).

The paper's §III-A index-set kernels read/write "a specified set of
indices" off a constant-memory table.  PR 1 gave permutations a plan
engine (`core/plan.py`) and PR 2 gave stencils one (`core/stencil.py`);
this module is the third leg — **data-dependent** movement — and follows
the same three-step contract:

1. **block** — the index table is reshaped to ``(nB, block_rows)`` row
   blocks so each grid step moves ``block_rows`` rows instead of one (the
   batching the paper gets from multi-row thread blocks).  In-kernel run
   detection collapses blocks whose indices form a contiguous run into a
   single strided block copy — the index-table analogue of PR 1's axis
   collapsing, but resolved at run time because the table is data.
2. **route** — pick the kernel for ``(semantics, shape)``:
   ``gather`` / ``scatter`` -> the blocked masked gather
   (`kernels.gather_scatter.gather_rows_blocked`; a scatter is executed
   as a gather through the inverted table), ``gather_combine`` -> the
   fused gather+weighted-combine kernel (ONE `pallas_call` for the whole
   MoE combine).  Degenerate sizes route to ``noop``/``oracle``.
3. **cache** — plans are memoized on ``(src_shape, dtype, n_out,
   semantics, masked, top_k)`` so steady-state serving steps pay zero
   planning overhead (repeated calls return the *identical* plan object).

Sentinel semantics: a negative index means "no source row" and the kernel
zero-fills (gather) or contributes zero (combine) — in-kernel masking via
``pl.when``, which is what lets `models.moe.moe_sort` drop its
sentinel-row concatenates.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import tune
from repro.core.plan import HBM_GBPS
from repro.kernels.tiling import (
    VMEM_BUDGET,
    cdiv,
    round_up,
    row_block_candidates,
    sublanes,
)
from repro.utils.roofline import movement_cost_s

#: semantics accepted by :func:`plan_index_op`.  ``ragged_rows`` is the
#: serving engine's pack/unpack route (DESIGN.md §12): a masked gather
#: whose table maps packed-prefill rows into per-slot ring rows — each
#: sequence's rows form a contiguous run, so the blocked kernel's run
#: detection collapses them into strided block copies.
SEMANTICS = ("gather", "scatter", "gather_combine", "ragged_rows")

#: row-block target: enough rows per grid step to amortize per-step
#: overhead without starving the double-buffered VMEM budget.
BLOCK_ROWS_TARGET = 64


@dataclass(frozen=True)
class IndexPlan:
    """Cached lowering decision for one index-set movement.

    Mirrors :class:`repro.core.plan.RearrangePlan`: the kernel route, the
    row-block geometry, and the predicted HBM traffic (data rows plus the
    int32 index-table stream) so callers and benchmarks can compare
    achieved vs predicted movement.

    Example::

        plan = plan_index_op((4096, 512), jnp.bfloat16, 4096, "gather")
        print(plan.describe())
    """

    semantics: str  # gather | scatter | gather_combine
    mode: str  # blocked | rowwise | oracle | noop
    kernel: str  # gather_rows_blocked | gather_combine_blocked | gather_rows | ref | noop
    n_src: int  # rows in the source array
    n_out: int  # rows produced
    row_elems: int  # elements per row (C)
    block_rows: int  # rows moved per grid step (br)
    grid: int  # number of row blocks (nB)
    table_rows: int  # padded index-table length (grid * block_rows [* top_k])
    masked: bool  # negative indices zero-fill
    top_k: int  # combine fan-in (1 for gather/scatter)
    bytes_moved: int  # data read + write + index-table traffic
    roofline_s: float  # bytes / HBM bandwidth (one chip)

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        return (
            f"{self.semantics}: {self.mode} kernel={self.kernel} "
            f"src={self.n_src}x{self.row_elems} out={self.n_out} "
            f"blocks={self.grid}x{self.block_rows} rows"
            f"{f' k={self.top_k}' if self.top_k > 1 else ''} "
            f"{self.bytes_moved/1e6:.2f} MB moved, "
            f"roofline {self.roofline_s*1e6:.1f} us @ {HBM_GBPS} GB/s"
        )


def _build_plan(
    n_src: int,
    row_elems: int,
    dtype_name: str,
    n_out: int,
    semantics: str,
    masked: bool,
    top_k: int,
    block_rows: int | None = None,
    engine: str | None = None,
) -> IndexPlan:
    """Route one index-set movement and materialize the plan.

    ``block_rows`` overrides the heuristic row-block height and
    ``engine="rowwise"`` forces the seed one-row-per-grid-step kernel
    (the tuner's hooks); with both defaults this is exactly the pre-tuner
    planner.
    """
    itemsize = jnp.dtype(dtype_name).itemsize

    def _mk(mode, kernel, br, grid, table_rows, bytes_moved):
        return IndexPlan(
            semantics=semantics,
            mode=mode,
            kernel=kernel,
            n_src=n_src,
            n_out=n_out,
            row_elems=row_elems,
            block_rows=br,
            grid=grid,
            table_rows=table_rows,
            masked=masked,
            top_k=top_k,
            bytes_moved=bytes_moved,
            roofline_s=bytes_moved / (HBM_GBPS * 1e9),
        )

    if n_out == 0 or row_elems == 0:
        return _mk("noop", "noop", 1, 0, 0, 0)
    if n_src == 0:
        # nothing to read: every index is a sentinel; output is zeros
        return _mk("noop", "noop", 1, 0, 0, n_out * row_elems * itemsize)

    # row-block geometry: full-width rows (long contiguous DMAs), the row
    # count bounded by the double-buffered VMEM budget.  Combine keeps
    # top_k source rows per output row resident, so its budget divides by k.
    sl = sublanes(dtype_name)
    row_bytes = max(row_elems * itemsize, 1)
    br_budget = max(VMEM_BUDGET // (2 * row_bytes * top_k), 1)
    br = min(round_up(BLOCK_ROWS_TARGET, sl), max(br_budget // sl * sl, sl), n_out)
    if block_rows is not None:
        br = min(int(block_rows), n_out)
    grid = cdiv(n_out, br)

    if engine == "rowwise":
        # the seed per-row kernel: one grid step per output row, no
        # sentinel masking, gather semantics only (the tuner offers this
        # engine only where those preconditions hold)
        if semantics != "gather" or masked or top_k != 1:
            raise ValueError("rowwise engine is unmasked gather-only")
        return _mk(
            "rowwise", "gather_rows", 1, n_out, n_out,
            2 * n_out * row_bytes + n_out * 4,
        )

    # traffic: each output row is one read + one write of row_bytes (upper
    # bound under masking), plus the int32 index-table stream; combine
    # reads top_k source rows and a float32 gate per (row, k).
    if semantics == "gather_combine":
        bytes_moved = (
            n_out * top_k * row_bytes  # source rows in
            + n_out * row_bytes  # combined rows out
            + n_out * top_k * 4  # back table
            + n_out * top_k * 4  # gates
        )
        return _mk(
            "blocked", "gather_combine_blocked", br, grid, grid * br * top_k, bytes_moved
        )
    bytes_moved = 2 * n_out * row_bytes + n_out * 4
    if semantics == "scatter":
        # executed as a masked gather through the inverted table; the
        # inversion itself is an int32 table op (n_src * 4 extra bytes)
        bytes_moved += n_src * 4
    return _mk("blocked", "gather_rows_blocked", br, grid, grid * br, bytes_moved)


@functools.lru_cache(maxsize=4096)
def _plan_cached(
    n_src: int,
    row_elems: int,
    dtype_name: str,
    n_out: int,
    semantics: str,
    masked: bool,
    top_k: int,
) -> IndexPlan:
    return _build_plan(n_src, row_elems, dtype_name, n_out, semantics, masked, top_k)


def _candidates(base: IndexPlan, dtype_name: str) -> list[tune.Candidate]:
    """The index engine's search space: the row-block neighborhood of the
    blocked kernel (heuristic first) plus — for unmasked single-fan-in
    gathers, where the two kernels are bit-identical — the seed rowwise
    engine as an engine-choice candidate."""
    itemsize = jnp.dtype(dtype_name).itemsize
    row_bytes = max(base.row_elems * itemsize, 1)
    cands = []
    for br in row_block_candidates(
        base.block_rows, base.n_out, row_bytes, dtype_name, base.top_k
    ):
        grid = cdiv(base.n_out, br)
        # padded table rows round the data traffic up to whole blocks
        padded = 2 * grid * br * row_bytes * max(base.top_k, 1)
        cands.append(
            tune.Candidate(
                label=f"br{br}",
                params=(("block_rows", br), ("engine", "blocked")),
                cost_s=movement_cost_s(padded, grid),
            )
        )
    if base.semantics == "gather" and not base.masked and base.top_k == 1:
        cands.append(
            tune.Candidate(
                label="rowwise",
                params=(("block_rows", 1), ("engine", "rowwise")),
                cost_s=movement_cost_s(2 * base.n_out * row_bytes, base.n_out),
            )
        )
    return cands


def _runner_factory(
    n_src: int, row_elems: int, dtype_name: str, n_out: int,
    semantics: str, masked: bool, top_k: int,
):
    """Measured-mode runner: execute one candidate plan on deterministic
    sample data through the dispatch layer's plan executor."""

    def factory(cand: tune.Candidate):
        import jax

        from repro.kernels import ops  # lazy: ops imports this module

        d = cand.param_dict()
        plan = _build_plan(
            n_src, row_elems, dtype_name, n_out, semantics, masked, top_k,
            block_rows=d["block_rows"], engine=d["engine"],
        )
        x = tune.sample_array((n_src, row_elems), dtype_name)
        rows = n_out * top_k if semantics == "gather_combine" else n_out
        idx = (jnp.arange(rows, dtype=jnp.int32) * 7919) % max(n_src, 1)
        if semantics == "gather_combine":
            idx = idx.reshape(n_out, top_k)
            gates = jnp.full((n_out, top_k), 1.0 / top_k, jnp.float32)
            fn = jax.jit(lambda a, i, g: ops.apply_index_plan(a, i, plan, gates=g))
            return lambda: fn(x, idx, gates)
        if semantics == "scatter":
            idx = (jnp.arange(n_src, dtype=jnp.int32) * 7919) % max(n_out, 1)
        fn = jax.jit(lambda a, i: ops.apply_index_plan(a, i, plan))
        return lambda: fn(x, idx)

    return factory


@functools.lru_cache(maxsize=4096)
def _plan_tuned_cached(
    n_src: int,
    row_elems: int,
    dtype_name: str,
    n_out: int,
    semantics: str,
    masked: bool,
    top_k: int,
    mode: str,
) -> IndexPlan:
    base = _plan_cached(n_src, row_elems, dtype_name, n_out, semantics, masked, top_k)
    if base.mode == "noop":
        return base  # nothing to tune: no kernel runs
    choice = tune.select(
        "index",
        f"src=({n_src},{row_elems})|dtype={dtype_name}|n_out={n_out}"
        f"|{semantics}|masked={masked}|k={top_k}",
        _candidates(base, dtype_name),
        _runner_factory(n_src, row_elems, dtype_name, n_out, semantics, masked, top_k),
        mode=mode,
    )
    d = choice.param_dict()
    if d["engine"] == "blocked" and d["block_rows"] == base.block_rows:
        return base  # heuristic won: tuned and untuned plans are the SAME object
    return _build_plan(
        n_src, row_elems, dtype_name, n_out, semantics, masked, top_k,
        block_rows=d["block_rows"], engine=d["engine"],
    )


def plan_index_op(
    src_shape: Sequence[int],
    dtype,
    n_out: int,
    semantics: str,
    *,
    masked: bool = False,
    top_k: int = 1,
    tuned: bool | None = None,
) -> IndexPlan:
    """Plan (and cache) an index-set movement.

    ``src_shape`` is the 2-D source array shape ``(n_src, C)``; ``n_out``
    the number of output rows (for ``scatter`` that is the *destination*
    row count); ``semantics`` one of ``gather | scatter | gather_combine |
    ragged_rows`` (the last is the serving engine's masked unpack gather
    over a :func:`ragged_layout`, DESIGN.md §12).
    ``masked`` enables sentinel handling (negative index -> zero row) and
    ``top_k`` is the combine fan-in.

    Example::

        plan = plan_index_op((1024, 256), jnp.float32, 2048, "gather",
                             masked=True)
        assert plan is plan_index_op((1024, 256), jnp.float32, 2048,
                                     "gather", masked=True)  # cached

    ``tuned=None`` (default) resolves from ``REPRO_TUNE``; ``tuned=True``
    routes through the autotuner (DESIGN.md §11): the row-block
    neighborhood — plus the rowwise engine where it is bit-identical — is
    measured (TPU) or cost-scored (elsewhere), same lru identity
    guarantees as untuned plans.
    """
    if semantics not in SEMANTICS:
        raise ValueError(f"unknown semantics {semantics!r}; want one of {SEMANTICS}")
    if semantics == "ragged_rows" and not masked:
        raise ValueError(
            "ragged_rows plans are always masked: rows past each sequence's "
            "length are sentinels (-1) that zero-fill the ring tail"
        )
    if len(src_shape) != 2:
        raise ValueError(f"index plans want 2-D sources, got {tuple(src_shape)}")
    if n_out < 0:
        raise ValueError(f"n_out must be >= 0, got {n_out}")
    if top_k < 1:
        raise ValueError(f"top_k must be >= 1, got {top_k}")
    n_src, row_elems = (int(s) for s in src_shape)
    if tuned is None:
        tuned = tune.tune_default()
    key = (
        n_src,
        row_elems,
        jnp.dtype(dtype).name,
        int(n_out),
        semantics,
        bool(masked),
        int(top_k),
    )
    if not tuned:
        return _plan_cached(*key)
    return _plan_tuned_cached(*key, tune.resolve_mode())


def index_plan_cache_info():
    """Expose the plan-memo stats (tests / benchmarks)."""
    return _plan_cached.cache_info()


# ---------------------------------------------------------------------------
# ragged packed layout (qo_indptr) — the serving engine's prefill route
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RaggedLayout:
    """``qo_indptr``-style packed layout for one ragged prefill batch
    (DESIGN.md §12): n variable-length prompts concatenated along one
    packed token axis, bucket-padded to ``t_pad``.

    The layout carries the masking tables the packed forward needs
    (``seg_ids``/``positions`` drive the block-diagonal causal mask and
    per-sequence RoPE) plus the pack/unpack geometry (``indptr``,
    :meth:`unpack_index`) the engine's ``ragged_rows`` IndexPlan gather
    uses to move packed KV rows into decode slots.  Zero-length sequences
    are legal at the layout level (an empty segment, all-sentinel unpack
    rows); admitting one is the *engine's* error.

    Example::

        lay = ragged_layout((3, 5), bucket=8)
        assert lay.indptr == (0, 3, 8) and lay.t_pad == 8
    """

    lengths: tuple[int, ...]  #: per-sequence prompt lengths
    bucket: int  #: packed-width rounding (compile-shape stability)
    total: int  #: sum of lengths
    t_pad: int  #: bucket-rounded packed width
    indptr: tuple[int, ...]  #: (n+1,) prefix sums — sequence j owns rows [indptr[j], indptr[j+1])
    seg_ids: np.ndarray = field(compare=False)  #: (t_pad,) int32 sequence id, -1 pad
    positions: np.ndarray = field(compare=False)  #: (t_pad,) int32 within-sequence position
    last_ix: np.ndarray = field(compare=False)  #: (n,) packed index of each sequence's last token

    def unpack_index(self, n_rows: int) -> np.ndarray:
        """The unpack gather table: (n_seq, n_rows) int32 mapping slot row
        s of sequence j to its packed row, ``-1`` (zero-fill sentinel)
        past the sequence's length — the operand for a ``ragged_rows``
        :func:`plan_index_op` gather."""
        n = len(self.lengths)
        out = np.full((n, n_rows), -1, np.int32)
        for j, ln in enumerate(self.lengths):
            take = min(ln, n_rows)
            out[j, :take] = np.arange(self.indptr[j], self.indptr[j] + take)
        return out


@functools.lru_cache(maxsize=1024)
def ragged_layout(lengths: tuple[int, ...], bucket: int = 64) -> RaggedLayout:
    """Plan (and cache) the packed layout for prompts of ``lengths``.

    Cached on the exact length tuple — steady-state admission waves with
    repeating shapes pay zero planning overhead, mirroring the other plan
    engines' memo contract."""
    lengths = tuple(int(x) for x in lengths)
    if not lengths:
        raise ValueError("ragged_layout needs at least one sequence")
    if any(x < 0 for x in lengths):
        raise ValueError(f"negative sequence length in {lengths}")
    if bucket < 1:
        raise ValueError(f"bucket must be >= 1, got {bucket}")
    total = sum(lengths)
    t_pad = max(round_up(max(total, 1), bucket), bucket)
    indptr = [0]
    for ln in lengths:
        indptr.append(indptr[-1] + ln)
    seg = np.full((t_pad,), -1, np.int32)
    pos = np.zeros((t_pad,), np.int32)
    last = np.zeros((len(lengths),), np.int32)
    for j, ln in enumerate(lengths):
        seg[indptr[j] : indptr[j + 1]] = j
        pos[indptr[j] : indptr[j + 1]] = np.arange(ln)
        last[j] = max(indptr[j + 1] - 1, indptr[j])  # undefined for ln == 0
    return RaggedLayout(
        lengths=lengths,
        bucket=int(bucket),
        total=total,
        t_pad=t_pad,
        indptr=tuple(indptr),
        seg_ids=seg,
        positions=pos,
        last_ix=last,
    )
