"""Batched serving example: continuous batching over fixed decode slots.

Where each serving stage lowers through the plan engines:

* **ragged admission** — every admission wave packs its prompts into ONE
  ``qo_indptr``-style prefill batch (`core/index_plan.py`'s
  ``ragged_layout``, DESIGN.md §12); the packed KV rows move into the
  decode slots via a masked ``ragged_rows`` IndexPlan gather — the §4
  index-set engine with ``-1`` sentinels zero-filling each ring tail.
* **chunked prefill** — prompts longer than ``chunk`` stream through
  `models.transformer.prefill_chunk` a slice per engine step, interleaved
  with decode, so a long prompt never stalls the live slots.
* **decode** — every step threads a per-slot position vector through
  `models.transformer.decode_step`; on kernel backends the attention is
  the split-KV `kernels.flash.flash_decode` two-stage reduce (§12), whose
  split count x block_k tile registers with the §11 autotuner.
* **MoE archs** — dispatch/combine is the §4 two-kernel sort path
  (`models/moe.py`); on a mesh, the expert-parallel variant
  (`moe_sort_ep`) wraps the same kernels in the §10 distributed planner.

The example asserts output identity: the engine's greedy tokens — across
slot reuse, ragged packing and chunked prefill — must equal a clean
per-request greedy decode (unpadded prefill + stepwise decode) on the
same fixed seed.

  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import Engine, Request, _write_slot

S_MAX = 128


def reference_greedy(cfg, params, prompt, max_new):
    """Single-request greedy decode: unpadded prefill + scalar-pos steps."""
    logits, c1 = tf.prefill(params, cfg, jnp.asarray(prompt)[None])
    ring = _write_slot(tf.init_cache(cfg, 1, S_MAX), c1, 0, S_MAX)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(out) < max_new and pos < S_MAX:
        lg, ring = tf.decode_step(
            params, cfg, jnp.asarray([out[-1]], np.int32), ring, jnp.int32(pos)
        )
        pos += 1
        out.append(int(jnp.argmax(lg[0])))
    return out


def main() -> None:
    cfg = configs.get_config("qwen2-7b-smoke")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(
        cfg, params, batch_slots=4, s_max=S_MAX, prompt_bucket=32,
        prefill_mode="ragged", chunk=16,  # ragged admission + chunked prefill
    )

    rng = np.random.default_rng(0)
    requests = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(8, 40))).astype(np.int32),
            max_new=12,
        )
        for i in range(10)  # 10 requests through 4 slots
    ]
    t0 = time.time()
    done = engine.run(requests)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {tokens} new tokens, {dt:.2f}s ({tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid} (prompt {len(r.prompt)} toks) -> {r.out[:6]}...")

    # identity with the clean per-request greedy decode on the same seed
    for r in done:
        ref = reference_greedy(cfg, params, r.prompt, r.max_new)
        assert r.out == ref, f"req {r.rid}: engine {r.out} != reference {ref}"
    print(f"identity: all {len(done)} outputs match the per-request reference")


if __name__ == "__main__":
    main()
