"""The autotuning layer: measured tile search with a persistent cache
(DESIGN.md §11).

Every plan engine (``core/plan.py`` §3, ``core/index_plan.py`` §4,
``core/stencil.py`` §9, ``core/dist_plan.py`` §10) runs the same three
steps — canonicalize, **route**, cache.  This module adds an optional
fourth step between route and cache: instead of trusting the one-shot
tiling heuristic, the planner enumerates a small neighborhood of legal
candidates (``kernels/tiling.py`` candidate API) and asks :func:`select`
to pick one.

Selection modes (resolved by :func:`resolve_mode` from ``REPRO_TUNE``):

* ``measure`` — time every candidate (:func:`time_candidates`, warmup +
  median) and persist the winner in the on-disk tuning cache, so
  steady-state serving/training pays zero tuning overhead across
  processes.  Only meaningful where kernels compile natively (TPU); under
  the Pallas interpreter, timings measure the interpreter, not the
  hardware.
* ``cost`` — rank candidates by the deterministic roofline cost model
  (``utils.roofline.movement_cost_s``), ties broken toward the heuristic
  (always the first candidate).  This is the automatic fallback off-TPU /
  under interpret mode, which is what keeps CI deterministic.

The tuner only ever changes *which* plan is cached — tile shapes, grid
order, or an engine choice between kernels proven bit-identical — never
the computed result (asserted in ``tests/test_tune.py``).

The disk cache (``REPRO_TUNE_CACHE``, default ``~/.cache/repro/tune.json``)
is a versioned JSON document keyed by plan-key string and scoped to one
``(backend, jax version)`` pair; stale, corrupt, or other-version files
are silently ignored and rebuilt, and writes are atomic
(write-temp-then-rename) so concurrent writers cannot clobber each other
into a torn file.
"""

from __future__ import annotations

import json
import os
import statistics
import tempfile
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Sequence

import jax

#: schema version of the on-disk cache; bump on any format change and old
#: files are rebuilt rather than misread.
SCHEMA_VERSION = 1

#: values of REPRO_TUNE that enable tuning for default (``tuned=None``) calls.
_ON_VALUES = ("on", "1", "measure", "cost")


@dataclass(frozen=True)
class Candidate:
    """One point in a planner's search space.

    ``label`` names the candidate (stable across processes — it is the
    persisted cache value); ``params`` carries the engine-specific plan
    overrides as a hashable ``((name, value), ...)`` tuple; ``cost_s`` is
    the roofline cost-model score used for deterministic selection.
    """

    label: str
    params: tuple
    cost_s: float

    def param_dict(self) -> dict:
        """The overrides as a plain dict (planner keyword arguments)."""
        return dict(self.params)


def tune_default() -> bool:
    """Whether ``tuned=None`` planner calls resolve to the tuned path.

    Off unless ``REPRO_TUNE`` is one of ``on | 1 | measure | cost`` — so
    with the variable unset or ``off`` (the CI default) every plan is the
    heuristic one, bit-identical to the untuned engines.
    """
    return os.environ.get("REPRO_TUNE", "off").lower() in _ON_VALUES


def resolve_mode() -> str:
    """The selection backend a tuned plan uses: ``measure`` or ``cost``.

    ``REPRO_TUNE=measure`` / ``REPRO_TUNE=cost`` force a backend; the
    default (``on``) measures only where timing reflects the hardware —
    a real TPU backend outside interpret mode — and cost-scores
    everywhere else (CPU containers, ``REPRO_PALLAS_INTERPRET=1``), so CI
    stays deterministic without configuration.
    """
    v = os.environ.get("REPRO_TUNE", "off").lower()
    if v == "measure":
        return "measure"
    if v == "cost":
        return "cost"
    from repro.kernels.tiling import force_interpret

    if jax.default_backend() == "tpu" and not force_interpret():
        return "measure"
    return "cost"


# ---------------------------------------------------------------------------
# the persistent tuning cache
# ---------------------------------------------------------------------------


def sample_array(shape: Sequence[int], dtype_name: str):
    """Deterministic sample operand for measured-mode runners: a small
    repeating ramp (``arange % 251``), cheap to build at any size and
    identical across processes so persisted winners are comparable.
    Shared by every planner's runner factory."""
    import jax.numpy as jnp

    n = 1
    for s in shape:
        n *= int(s)
    return (
        (jnp.arange(n, dtype=jnp.float32) % 251).astype(dtype_name).reshape(shape)
    )


def cache_path() -> Path:
    """Where the tuning cache lives: ``REPRO_TUNE_CACHE`` or the default
    ``~/.cache/repro/tune.json``."""
    p = os.environ.get("REPRO_TUNE_CACHE", "")
    if p:
        return Path(p)
    return Path.home() / ".cache" / "repro" / "tune.json"


def _scope() -> dict:
    """The (schema, backend, jax) triple one cache file is valid for."""
    return {
        "schema": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
    }


def load_cache() -> dict:
    """Read the tuning cache; ``{}`` entries when the file is missing,
    unparseable, from another schema version, or recorded on a different
    backend / jax version (a stale cache is ignored, never trusted)."""
    path = cache_path()
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError):
        return {**_scope(), "entries": {}}
    scope = _scope()
    if not isinstance(doc, dict) or any(doc.get(k) != v for k, v in scope.items()):
        return {**scope, "entries": {}}
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        return {**scope, "entries": {}}
    return {**scope, "entries": entries}


def store_entry(key: str, record: dict) -> None:
    """Merge one winner record into the on-disk cache, atomically.

    Load-modify-write with a temp file + ``os.replace`` in the cache's
    directory: a concurrent writer can win the race for the *file* (last
    rename wins whole-file), but no reader ever observes a torn document.
    Unwritable cache locations are ignored — tuning still works, it just
    re-measures per process.
    """
    path = cache_path()
    doc = load_cache()
    doc["entries"][key] = record
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            prefix=path.name + ".", dir=str(path.parent)
        )
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except OSError:
        pass


def lookup(key: str) -> dict | None:
    """The persisted winner record for ``key``, if any."""
    return load_cache()["entries"].get(key)


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


def time_candidates(
    candidates: Sequence[Candidate],
    runner_factory: Callable[[Candidate], Callable[[], object]],
    *,
    warmup: int = 1,
    iters: int = 5,
) -> list[float]:
    """Median wall-clock seconds per candidate.

    ``runner_factory(candidate)`` builds a zero-argument callable that
    executes one full candidate run (inputs pre-built, typically jitted);
    each candidate gets ``warmup`` untimed calls (compilation) and the
    median of ``iters`` timed calls with device sync.  A candidate whose
    runner raises scores ``inf`` (illegal configurations lose, they don't
    crash the tune).
    """
    out = []
    for cand in candidates:
        try:
            fn = runner_factory(cand)
            for _ in range(warmup):
                jax.block_until_ready(fn())
            samples = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                samples.append(time.perf_counter() - t0)
            out.append(statistics.median(samples))
        except Exception:  # noqa: BLE001 — an illegal candidate just loses
            out.append(float("inf"))
    return out


def select(
    engine: str,
    key: str,
    candidates: Sequence[Candidate],
    runner_factory: Callable[[Candidate], Callable[[], object]] | None,
    *,
    mode: str | None = None,
    persist: bool = True,
) -> Candidate:
    """Pick one candidate for plan key ``key`` of ``engine``.

    The contract every planner relies on:

    * the heuristic candidate is ``candidates[0]`` and wins all ties, so
      a tuned plan degrades to the untuned plan, never past it;
    * ``cost`` mode is pure arithmetic over ``Candidate.cost_s`` —
      deterministic, no I/O;
    * ``measure`` mode consults the persistent cache first (a recorded
      winner whose label still exists in the candidate set short-circuits
      the timing entirely), then times the field and persists the winner
      (``persist=False`` for keys that are not stable across processes,
      e.g. stencil programs with opaque Python functors);
    * no runner (``runner_factory=None``) always falls back to ``cost``
      — the distributed planner tunes this way because re-materializing a
      mesh inside a cached planner is not possible.
    """
    if not candidates:
        raise ValueError(f"{engine}: empty candidate set for {key!r}")
    if len(candidates) == 1:
        return candidates[0]
    if mode is None:
        mode = resolve_mode()
    if mode == "measure" and runner_factory is not None:
        full_key = f"{engine}|{key}"
        if persist:
            rec = lookup(full_key)
            if rec is not None:
                for cand in candidates:
                    if cand.label == rec.get("label"):
                        return cand
                # recorded winner no longer enumerated (code moved on):
                # fall through and re-tune
        timings = time_candidates(candidates, runner_factory)
        best = min(range(len(candidates)), key=lambda i: (timings[i], i))
        if timings[best] == float("inf"):
            # every candidate failed to run (transient device trouble, OOM
            # on the sample input): keep the heuristic but do NOT persist —
            # a recorded winner would short-circuit re-tuning forever, and
            # Infinity is not valid strict JSON
            return candidates[0]
        if persist:
            record = {
                "label": candidates[best].label,
                "params": candidates[best].param_dict(),
                "us": round(timings[best] * 1e6, 2),
                "n_candidates": len(candidates),
                "mode": "measure",
            }
            if timings[0] != float("inf"):
                # omitted when the heuristic itself failed to run —
                # Infinity is not valid strict JSON
                record["us_heuristic"] = round(timings[0] * 1e6, 2)
            store_entry(full_key, record)
        return candidates[best]
    # deterministic fallback: roofline cost model, first-wins ties
    return min(candidates, key=lambda c: c.cost_s)
