"""Sharding-rule tests (pure spec logic — no devices needed) plus a
subprocess mini dry-run on 8 forced host devices."""

import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.sharding import partition


MESH_AXES = {"model": "model", "data": "data", "model_size": 16, "data_size": 16}


def spec_of(arch, path, shape):
    cfg = configs.get_config(arch)
    return partition.param_spec(path, shape, cfg=cfg, mesh_axes=MESH_AXES)


def test_megatron_col_row_rules():
    assert spec_of("qwen2-7b", "stages/0/b0/attn/w_qkv", (28, 3584, 4608)) == P(None, None, "model")
    assert spec_of("qwen2-7b", "stages/0/b0/attn/w_o", (28, 3584, 3584)) == P(None, "model", None)
    assert spec_of("qwen2-7b", "stages/0/b0/mlp/w_up", (28, 3584, 18944)) == P(None, None, "model")
    assert spec_of("qwen2-7b", "stages/0/b0/mlp/w_down", (28, 18944, 3584)) == P(None, "model", None)


def test_norms_replicated():
    assert spec_of("qwen2-7b", "stages/0/b0/attn/norm/scale", (28, 3584)) == P(None, None)


def test_vocab_parallel_embedding():
    assert spec_of("qwen2-7b", "embed/tok", (152064, 3584)) == P("model", None)


def test_indivisible_dims_stay_replicated():
    # 28 heads * 128 = 3584 divisible, but a 30-wide dim is not
    assert spec_of("qwen2-7b", "stages/0/b0/attn/w_qkv", (28, 3584, 30)) == P(None, None, None)


def test_expert_sharding_modes():
    # deepseek: 64 experts / 16 shards -> expert axis sharded
    s = spec_of("deepseek-moe-16b", "stages/1/b0/moe/w_up", (27, 64, 2048, 1408))
    assert s == P(None, "model", None, None)
    # mixtral: 8 experts < 16 -> TP inside experts on the ff dim
    s = spec_of("mixtral-8x7b", "stages/0/b0/moe/w_up", (32, 8, 4096, 14336))
    assert s[3] == "model" or s[1] == "model"  # ffn sharded (+ fsdp may add data)


def test_fsdp_adds_data_axis():
    s = spec_of("llama-3.2-vision-90b", "stages/0/b0/mlp/w_up", (20, 8192, 28672))
    assert "model" in s and "data" in s


def test_zero1_spec():
    z = partition.zero1_spec(P(None, "model"), (4096, 14336), data_axis="data", data_size=16)
    assert z == P("data", "model")
    # no divisible free axis -> unchanged
    z = partition.zero1_spec(P(None, "model"), (30, 14336), data_axis="data", data_size=16)
    assert z == P(None, "model")


def test_filter_spec_drops_missing_axes():
    assert partition.filter_spec(P(("pod", "data"), "model"), ("data", "model")) == P(
        ("data",), "model"
    )
    assert partition.filter_spec(P("pod", None), ("data", "model")) == P(None, None)


def test_cache_leaf_spec_prefers_heads_then_seq():
    # (count, B, Hkv, S, hd): heads divisible -> model on heads
    s = partition.cache_leaf_spec((28, 128, 16, 32768, 128), ("data",), model_size=16)
    assert s == P(None, ("data",), "model", None, None)
    # heads=4 not divisible -> sequence sharded
    s = partition.cache_leaf_spec((28, 128, 4, 32768, 128), ("data",), model_size=16)
    assert s == P(None, ("data",), None, "model", None)


def test_batch_pspec_divisibility():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert partition.batch_pspec(256, FakeMesh()) == ("data",)
    assert partition.batch_pspec(1, FakeMesh()) is None


@pytest.mark.slow
def test_mini_dryrun_8dev_subprocess(tmp_path):
    """End-to-end SPMD proof on 8 forced host devices (own process so the
    main test process keeps its single-device view)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_BF16_DOT"] = "1"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.launch import specs
from repro.models import transformer as tf
from repro.optim import adamw

cfg = configs.get_config("qwen2-7b-smoke").with_(n_layers=2)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
cfg = cfg.with_(attn_shard="head")  # 4 heads / 4-way model axis
step = specs.make_step(cfg, configs.SHAPE_CELLS["train_4k"], mesh)
params_abs = tf.abstract_params(cfg)
pshard = specs.param_shardings(cfg, mesh)
oshard = specs.opt_shardings(cfg, mesh)
opt_abs = jax.eval_shape(adamw.init, params_abs)
inputs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
          "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
in_sh = {"tokens": NamedSharding(mesh, P("data", None)),
         "labels": NamedSharding(mesh, P("data", None))}
from repro.launch.mesh import set_mesh_compat
with set_mesh_compat(mesh):
    lowered = jax.jit(step, in_shardings=(pshard, oshard, in_sh),
                      out_shardings=(pshard, oshard, None)).lower(params_abs, opt_abs, inputs)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca  # jax 0.4.x returns [dict]
print("COMPILED_OK", ca.get("flops", 0) > 0)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "COMPILED_OK True" in r.stdout, r.stderr[-2000:]
