"""Generic 2-D stencil kernel (paper §III-D), TPU-native.

The CUDA kernel loads a 34x34 halo'd tile for a 32x32 block (overlapping,
partially uncoalesced apron loads; texture-memory variants to soften the
misalignment) and takes a *functor* for the per-point computation so any
stencil compiles to a specialized kernel.

TPU version:
* row-panel decomposition: each grid step owns a (block_rows, W) panel with
  the full row width resident in VMEM — column halos are then free (they
  are just lane shifts within the panel), which deletes the paper's
  misaligned-apron problem instead of patching it with texture fetches.
* the row halo is expressed by passing the input *three times* with
  clamped index maps (prev / cur / next panel).  The Pallas pipeline DMAs
  each as a full lane-aligned tile — the overlap costs one extra panel load
  per block, the same 2*r/block_rows redundancy the paper reports, but
  every load stays aligned.
* boundary handling and partial-final-block garbage are killed in one move
  by masking rows against their *global* row index (zero boundary).
* the functor runs at **trace time** — the exact analogue of the paper's
  compile-time C++ functor: any jnp expression over ``shift(dy, dx)`` views
  specializes the kernel with no interpretive overhead.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import cdiv, force_interpret, sublanes


def _stencil_kernel(functor, radius, br, H, W, prev_ref, cur_ref, next_ref, o_ref):
    i = pl.program_id(0)
    tile = jnp.concatenate([prev_ref[...], cur_ref[...], next_ref[...]], axis=0)
    # rows [br - r, 2*br + r) of the 3-panel tile == halo'd panel (br+2r, W)
    sub = jax.lax.slice_in_dim(tile, br - radius, 2 * br + radius, axis=0)
    # zero rows that fall outside the domain (handles both the boundary
    # condition and OOB garbage in the final partial panel).  2-D iota —
    # Mosaic requires >=2-D iota on TPU.
    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (br + 2 * radius, 1), 0)
    grow = i * br + rows_iota - radius  # global row ids, (br+2r, 1)
    valid = (grow >= 0) & (grow < H)
    sub = jnp.where(valid, sub, jnp.zeros((), sub.dtype))
    # zero-pad columns for the lane-shift halo
    subp = jnp.pad(sub, ((0, 0), (radius, radius)))

    def shift(dy: int, dx: int) -> jax.Array:
        if max(abs(dy), abs(dx)) > radius:
            raise ValueError(f"shift ({dy},{dx}) exceeds radius {radius}")
        return jax.lax.slice(
            subp, (radius + dy, radius + dx), (radius + dy + br, radius + dx + W)
        )

    o_ref[...] = functor(shift)


@functools.partial(
    jax.jit, static_argnames=("functor", "radius", "block_rows", "interpret")
)
def stencil2d_functor(
    x: jax.Array,
    functor: Callable,
    radius: int,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply a generic stencil functor over a 2-D grid (zero boundary).

    ``functor(shift)`` -> Array, where ``shift(dy, dx)`` yields the panel
    shifted by (dy, dx).  See ``repro.kernels.ref.stencil2d_functor`` for
    the oracle semantics.
    """
    if x.ndim != 2:
        raise ValueError(f"stencil2d wants 2-D input, got {x.shape}")
    H, W = x.shape
    sl = sublanes(x.dtype)
    br = block_rows or max(sl, min(64, H))
    if radius > br:
        raise ValueError(f"radius {radius} > block_rows {br}")
    nb = cdiv(H, br)

    in_specs = [
        pl.BlockSpec((br, W), lambda i: (jnp.maximum(i - 1, 0), 0)),
        pl.BlockSpec((br, W), lambda i: (i, 0)),
        pl.BlockSpec((br, W), lambda i: (jnp.minimum(i + 1, nb - 1), 0)),
    ]
    interpret = force_interpret() if interpret is None else interpret
    return pl.pallas_call(
        functools.partial(_stencil_kernel, functor, radius, br, H, W),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), x.dtype),
        interpret=interpret,
    )(x, x, x)


def stencil2d(
    x: jax.Array,
    offsets,
    weights,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Weighted-sum stencil via the functor kernel (zero boundary)."""
    radius = max(max(abs(dy), abs(dx)) for dy, dx in offsets)
    offs = tuple((int(dy), int(dx)) for dy, dx in offsets)
    wts = tuple(float(w) for w in weights)

    def functor(shift):
        acc = None
        for (dy, dx), w in zip(offs, wts):
            term = w * shift(dy, dx)
            acc = term if acc is None else acc + term
        return acc

    return stencil2d_functor(
        x, functor, radius, block_rows=block_rows, interpret=interpret
    )
