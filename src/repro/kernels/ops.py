"""Dispatch layer: one public op per kernel, Pallas on TPU / oracle elsewhere.

Dispatch rules
--------------
* On TPU the Pallas kernels own the fast path.
* On CPU/GPU the jnp oracles (``ref.py``) are the dispatch target — XLA
  fuses them competitively, and (critically for this container) the
  multi-pod **dry-run compiles the XLA path**, keeping HLO clean for the
  roofline analysis.
* ``REPRO_PALLAS_INTERPRET=1`` forces every op through the Pallas kernel in
  interpret mode — this is how the test suite validates kernel semantics
  on CPU.
* Kernels have alignment preconditions (lane divisibility etc.).  When an
  input violates them, the op silently falls back to the oracle — the
  library never fails on an odd shape, it just loses the fast path (same
  contract as the paper's library).
"""

from __future__ import annotations

import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import (
    copy as copy_k,
    gather_scatter as gs_k,
    interlace as il_k,
    permute3d as p3_k,
    ref,
    reorder_nd as rnd_k,
    stencil2d as st_k,
)

Array = jax.Array


def _platform() -> str:
    return jax.devices()[0].platform


def use_pallas() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return True
    if os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1":
        return False
    return _platform() == "tpu"


def _interpret() -> bool:
    return _platform() != "tpu"


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------


def copy(x: Array) -> Array:
    if use_pallas():
        try:
            return copy_k.copy(x, interpret=_interpret())
        except ValueError:
            pass
    return ref.copy(x)


def copy_range(x: Array, start, size: int) -> Array:
    if use_pallas() and x.ndim == 2:
        return copy_k.copy_range(x, start, size, interpret=_interpret())
    return ref.copy_range(x, start, size)


def gather_rows(x: Array, idx: Array) -> Array:
    if use_pallas() and x.ndim == 2:
        return gs_k.gather_rows(x, idx, interpret=_interpret())
    return ref.gather_rows(x, idx)


def scatter_rows(x: Array, idx: Array, num_out: int | None = None) -> Array:
    if (
        use_pallas()
        and x.ndim == 2
        and (num_out is None or num_out == x.shape[0])
    ):
        return gs_k.scatter_rows(x, idx, interpret=_interpret())
    return ref.scatter_rows(x, idx, num_out)


def transpose2d_batched(x: Array, *, diagonal: bool = False) -> Array:
    if use_pallas():
        return p3_k.transpose2d_batched(x, diagonal=diagonal, interpret=_interpret())
    return ref.transpose2d_batched(x)


def permute(x: Array, perm: Sequence[int], *, grid_order: str = "out") -> Array:
    perm = tuple(int(p) for p in perm)
    if use_pallas():
        return rnd_k.permute_nd(x, perm, grid_order=grid_order, interpret=_interpret())
    return ref.permute(x, perm)


def reorder_nm(
    x: Array,
    perm: Sequence[int],
    base: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
) -> Array:
    """N->M reorder: window select + permute + squeeze (paper §III-B)."""
    if base is None and sizes is None and len(perm) == x.ndim:
        return permute(x, perm)
    # windowed form: slice via oracle (cheap, contiguousable), permute via kernel
    nd = x.ndim
    base_l = [0] * nd if base is None else list(base)
    sizes_l = list(x.shape) if sizes is None else list(sizes)
    window = jax.lax.dynamic_slice(x, base_l, sizes_l)
    kept = [int(p) for p in perm]
    full_perm = kept + [ax for ax in range(nd) if ax not in set(kept)]
    moved = permute(window, full_perm) if use_pallas() else ref.permute(window, full_perm)
    return moved.reshape(tuple(sizes_l[ax] for ax in kept))


def interlace(arrays: Sequence[Array]) -> Array:
    arrays = list(arrays)
    if use_pallas() and all(a.ndim == 1 for a in arrays):
        try:
            return il_k.interlace(tuple(arrays), interpret=_interpret())
        except ValueError:
            pass
    return ref.interlace(arrays)


def deinterlace(x: Array, n: int) -> list[Array]:
    if use_pallas() and x.ndim == 1:
        try:
            return list(il_k.deinterlace(x, n, interpret=_interpret()))
        except ValueError:
            pass
    return ref.deinterlace(x, n)


def stencil2d(
    x: Array,
    offsets,
    weights,
    *,
    boundary: str = "zero",
) -> Array:
    if use_pallas() and boundary == "zero" and x.ndim == 2:
        return st_k.stencil2d(x, offsets, weights, interpret=_interpret())
    return ref.stencil2d(x, offsets, weights, boundary=boundary)


def stencil2d_functor(
    x: Array,
    functor: Callable,
    radius: int,
    *,
    boundary: str = "zero",
) -> Array:
    if use_pallas() and boundary == "zero" and x.ndim == 2:
        return st_k.stencil2d_functor(x, functor, radius, interpret=_interpret())
    return ref.stencil2d_functor(x, functor, radius, boundary=boundary)
