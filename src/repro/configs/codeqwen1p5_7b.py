"""CodeQwen1.5-7B [hf: Qwen/CodeQwen1.5-7B] — qwen1.5 architecture
(QKV bias, full MHA-as-GQA kv=32)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    head_dim=128,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    unit=("attn",),
    source="hf:Qwen/CodeQwen1.5-7B",
)
