"""The paper's primary contribution: a composable data-rearrangement
library — layout algebra, movement planner, rearrange API, stencil API.

Public surface::

    from repro.core import rearrange, stencil, layout, plan
    rearrange.permute / permute_order / reorder / interlace / deinterlace
    rearrange.split_heads / merge_heads / space_to_depth / ...
    stencil.Stencil / fd_laplacian / apply_functor / conv1d_depthwise
"""

from repro.core import layout, plan, rearrange, stencil  # noqa: F401
