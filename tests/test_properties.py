"""Property-based tests (hypothesis) on the library's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import layout
from repro.core.plan import plan_rearrange
from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


def perms(n):
    return st.permutations(list(range(n)))


shapes_and_perms = st.integers(2, 5).flatmap(
    lambda n: st.tuples(
        st.tuples(*[st.integers(1, 6) for _ in range(n)]),
        st.permutations(list(range(n))),
    )
)


@given(st.integers(1, 6).flatmap(perms))
def test_paper_order_perm_roundtrip(order):
    perm = layout.paper_order_to_perm(order)
    assert sorted(perm) == list(range(len(order)))
    back = layout.perm_to_paper_order(perm)
    assert tuple(back) == tuple(order)


@given(st.integers(1, 6).flatmap(perms))
def test_invert_perm(perm):
    inv = layout.invert_perm(perm)
    assert layout.compose_perm(perm, inv) == tuple(range(len(perm)))
    assert layout.compose_perm(inv, perm) == tuple(range(len(perm)))


@given(shapes_and_perms)
def test_coalesce_preserves_semantics(sp):
    shape, perm = sp
    x = np.arange(int(np.prod(shape))).reshape(shape)
    want = np.transpose(x, perm)
    cshape, cperm, _ = layout.coalesce(shape, perm)
    got = np.transpose(x.reshape(cshape), cperm)
    assert got.size == want.size
    np.testing.assert_array_equal(got.reshape(want.shape), want)


@given(shapes_and_perms)
def test_canonicalize_mode_is_consistent(sp):
    shape, perm = sp
    canon = layout.canonicalize(shape, perm)
    assert canon.mode in ("identity", "copy", "transpose")
    if canon.mode == "transpose":
        # output-fastest axis differs from input-fastest axis
        assert canon.perm[-1] != len(canon.shape) - 1
    if canon.mode == "copy":
        assert canon.perm[-1] == len(canon.shape) - 1


@given(shapes_and_perms)
def test_permute_inverse_is_identity(sp):
    shape, perm = sp
    x = jnp.asarray(np.random.default_rng(0).standard_normal(shape), jnp.float32)
    y = ops.permute(x, perm)
    back = ops.permute(y, layout.invert_perm(perm))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(st.integers(2, 9), st.integers(1, 8))
def test_interlace_deinterlace_roundtrip(n, blocks):
    length = 128 * blocks
    rng = np.random.default_rng(n)
    arrays = [jnp.asarray(rng.standard_normal(length), jnp.float32) for _ in range(n)]
    il = ops.interlace(arrays)
    back = ops.deinterlace(il, n)
    for a, b in zip(arrays, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # interlace element law: out[j*n + k] == arrays[k][j]
    j, k = int(rng.integers(0, length)), int(rng.integers(0, n))
    assert float(il[j * n + k]) == float(arrays[k][j])


@given(st.integers(1, 4))
def test_stencil_linearity(order):
    rng = np.random.default_rng(order)
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    offs, wts = ref.fd_stencil_offsets(order)
    lhs = ref.stencil2d(x + 2.0 * y, offs, wts)
    rhs = ref.stencil2d(x, offs, wts) + 2.0 * ref.stencil2d(y, offs, wts)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


@given(shapes_and_perms)
def test_plan_invariants(sp):
    shape, perm = sp
    plan = plan_rearrange(shape, jnp.float32, perm)
    n = int(np.prod(shape))
    assert plan.bytes_moved == 2 * n * 4
    assert plan.roofline_s >= 0
    assert plan.block_r >= 1 and plan.block_c >= 1


@given(st.permutations(list(range(4))))
def test_kernel_matches_oracle_property(perm):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 4, 5, 16)), jnp.float32)
    from repro.kernels import reorder_nd

    got = reorder_nd.permute_nd(x, tuple(perm), interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.transpose(np.asarray(x), perm)
    )
