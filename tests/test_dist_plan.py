"""Distributed plan engine: decompose -> local plan -> cache (DESIGN.md §10).

Two-process layout (same pattern as the launcher dry-run): the *planner*
tests are pure metadata and run in the normal tier-1 process; the
*execution* tests need an 8-device mesh, so a single launcher test re-runs
this file in a subprocess with ``--xla_force_host_platform_device_count=8``
and ``REPRO_DIST_CHILD=1`` (the recipe ``make test-dist`` runs directly).

Execution coverage (child process):
* sharded permute (local / all_to_all / replicate strategies), sharded
  interlace — bit-identical to the single-device path on 1x2 / 1x4 / 2x4
  meshes, fp32 + bf16, ragged dims and zero-size shards;
* halo-exchanged ``repeat(k)`` stencil programs — bit-identical for all
  four boundary modes, one ``ppermute`` pair per k-block in the jaxpr;
* expert-parallel ``moe_sort`` — bit-identical to dropless single-device
  sort dispatch, exactly one ``all_to_all`` per direction in the jaxpr;
* plan-cache identity across calls, and the Pallas-interpret dispatch mode
  for each workload (the local plans run the real kernels per shard).
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import dist_plan as dp
from repro.core import stencil as st

_CHILD = os.environ.get("REPRO_DIST_CHILD") == "1"
needs_mesh = pytest.mark.skipif(
    not _CHILD,
    reason="needs 8 forced host devices — run via make test-dist "
    "(the launcher test spawns the same thing as a subprocess)",
)

RNG = np.random.default_rng(7)
MESHES = [((1, 2), "b"), ((1, 4), "b"), ((2, 4), "b")]
DTYPES = [jnp.float32, jnp.bfloat16]

JACOBI = st.Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)


def rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def make_mesh(shape):
    from repro.launch.mesh import make_mesh_compat

    return make_mesh_compat(shape, ("a", "b")[: len(shape)])


def jaxpr_counts(fn, *args) -> dict:
    """Count collective primitive applications in the traced jaxpr (the
    ``prim[params]`` spelling — plain substrings would also match param
    names like ``all_gather_dimension``)."""
    s = str(jax.make_jaxpr(fn)(*args))
    return {
        "all_to_all": s.count("all_to_all["),
        "ppermute": s.count("ppermute["),
        "all_gather": s.count("all_gather["),
    }


# ---------------------------------------------------------------------------
# planner: strategy choice, cost model, cache (no devices needed)
# ---------------------------------------------------------------------------

MK4 = (("a", 1), ("b", 4))


def test_plan_local_when_sharding_rides_the_perm():
    p = dp.plan_dist_rearrange(MK4, P("b"), None, (8, 6, 12), jnp.float32, (1, 0, 2))
    assert p.strategy == "local" and p.bytes_on_wire == 0 and p.collectives == ()
    assert p.out_spec == (None, "b", None)  # sharding carried to position 1
    # the reused local plan is the per-shard shape
    assert p.local_key[0] == (2, 6, 12)


def test_plan_all_to_all_cost_model():
    p = dp.plan_dist_rearrange(
        MK4, P("b"), P(None, None, "b"), (8, 6, 12), jnp.float32, (1, 0, 2)
    )
    assert p.strategy == "all_to_all" and p.collectives == ("all_to_all",)
    gbytes = 8 * 6 * 12 * 4
    assert p.bytes_on_wire == gbytes * 3 // 4  # (P-1)/P of the array
    a, b, psz = p.detail
    assert (a, b, psz) == (0, 2, 4)
    assert p.local_key[0] == (8, 6, 3)  # re-sharded local shape
    assert "all_to_all" in p.describe()


def test_plan_replicate_fallback():
    # explicit fully-replicated output: no aligned all_to_all exists, the
    # planner falls back to all_gather (the "unshard this" request)
    p = dp.plan_dist_rearrange(
        MK4, P("b"), P(None, None, None), (8, 10, 12), jnp.float32, (1, 0, 2)
    )
    assert p.strategy == "replicate" and "all_gather" in p.collectives
    gbytes = 8 * 10 * 12 * 4
    assert p.bytes_on_wire == gbytes * 3  # every dev pulls 3 remote shards
    # cross-mesh-axis reshard has no aligned collective either
    p2 = dp.plan_dist_rearrange(
        (("a", 2), ("b", 4)), P("b"), P(None, None, "a"),
        (8, 10, 12), jnp.float32, (1, 0, 2),
    )
    assert p2.strategy == "replicate" and p2.detail[1] == ((2, "a"),)


def test_plan_rejects_unshardable():
    with pytest.raises(ValueError, match="not divisible"):
        dp.plan_dist_rearrange(MK4, P("b"), None, (6, 4), jnp.float32, (1, 0))
    with pytest.raises(ValueError, match="bad perm"):
        dp.plan_dist_rearrange(MK4, P("b"), None, (8, 4), jnp.float32, (0, 0))


def test_plan_shard_request_on_replicated_input_slices():
    # replicated in, sharded out: must NOT plan "local" (each shard would
    # return the full array and shard_map would mis-assemble) — it slices
    p = dp.plan_dist_rearrange(
        MK4, P(), P(None, "b"), (8, 6, 12), jnp.float32, (1, 0, 2)
    )
    assert p.strategy == "replicate" and p.bytes_on_wire == 0
    assert p.detail == ((), ((1, "b"),))  # no gathers, one slice
    # size-1 mesh axes shard nothing: any request over them stays local
    p2 = dp.plan_dist_rearrange(
        MK4, P("a"), P(None, "a"), (8, 6, 12), jnp.float32, (1, 0, 2)
    )
    assert p2.strategy == "local"


def test_plan_wire_bytes_count_replica_groups():
    # a collective over 'b' on an (a=2, b=4) mesh runs in BOTH a-groups:
    # total wire is 2x the per-group cost
    mk24 = (("a", 2), ("b", 4))
    gbytes = 8 * 6 * 12 * 4
    p1 = dp.plan_dist_rearrange(
        MK4, P("b"), P(None, None, "b"), (8, 6, 12), jnp.float32, (1, 0, 2)
    )
    p2 = dp.plan_dist_rearrange(
        mk24, P("b"), P(None, None, "b"), (8, 6, 12), jnp.float32, (1, 0, 2)
    )
    assert p1.bytes_on_wire == gbytes * 3 // 4
    assert p2.bytes_on_wire == 2 * p1.bytes_on_wire


def test_plan_multiaxis_sharding_stays_local_when_carried():
    # a dim sharded over BOTH mesh axes still permutes comm-free when the
    # output sharding rides the perm (shard_div divides by the product)
    p = dp.plan_dist_rearrange(
        (("a", 2), ("b", 4)), P(("a", "b")), None, (16, 6, 12), jnp.float32,
        (1, 0, 2),
    )
    assert p.strategy == "local" and p.bytes_on_wire == 0
    assert p.local_key[0] == (2, 6, 12)  # 16 / (2*4)


def test_plan_multiaxis_gather_order_minor_first():
    # replicate fallback on a multi-axis-sharded dim must all_gather the
    # MINOR axis first (major-first interleaves the blocks)
    p = dp.plan_dist_rearrange(
        (("a", 2), ("b", 4)), P(("a", "b")), P(None, None, None),
        (16, 6, 12), jnp.float32, (1, 0, 2),
    )
    assert p.strategy == "replicate"
    assert p.detail[0] == ((0, "b"), (0, "a"))  # minor 'b' gathered first


def test_plan_cache_identity():
    a = dp.plan_dist_rearrange(MK4, P("b"), None, (8, 6, 12), jnp.bfloat16, (2, 1, 0))
    b = dp.plan_dist_rearrange(MK4, P("b"), None, (8, 6, 12), jnp.bfloat16, (2, 1, 0))
    assert a is b
    # PartitionSpec and pre-normalized tuples hit the same key
    c = dp.plan_dist_rearrange(MK4, ("b", None, None), None, (8, 6, 12),
                               np.dtype("bfloat16"), (2, 1, 0))
    assert c is a
    before = dp.dist_plan_cache_info()["rearrange"].hits
    dp.plan_dist_rearrange(MK4, P("b"), None, (8, 6, 12), jnp.bfloat16, (2, 1, 0))
    assert dp.dist_plan_cache_info()["rearrange"].hits == before + 1


def test_plan_interlace_always_commfree():
    for spec in (P("b"), P(None, "b"), P()):
        p = dp.plan_dist_interlace(MK4, spec, (8, 16), jnp.float32, 3)
        assert p.strategy == "local" and p.bytes_on_wire == 0
        assert p.out_spec == p.in_spec


def test_plan_stencil_kblock_partition_and_wire():
    prog = JACOBI.repeat(12)
    p = dp.plan_dist_stencil(MK4, "b", (32, 16), jnp.float32, prog.stages, "zero")
    # Hl = 8 rows/shard, 12 radius-1 stages -> blocks of 8 + 4 stages
    assert p.strategy == "halo" and p.detail == ((8, 8), (4, 4))
    assert p.collectives == ("ppermute",) * 4  # one pair per k-block
    assert p.bytes_on_wire == (2 * 8 + 2 * 4) * 16 * 4 * 4
    a = dp.plan_dist_stencil(MK4, "b", (32, 16), jnp.float32, prog.stages, "zero")
    assert a is p


def test_plan_stencil_replicates_when_radius_exceeds_shard():
    big = st.fd_laplacian(3)  # radius 3 > Hl = 2
    p = dp.plan_dist_stencil(
        (("x", 8),), "x", (16, 16), jnp.float32, big.as_program().stages, "zero"
    )
    assert p.strategy == "replicate" and p.collectives == ("all_gather",)


def test_plan_moe_cost_model():
    p = dp.plan_dist_moe(MK4, "b", 32, 16, 8, 8, 2, jnp.float32)
    assert p.strategy == "ep" and p.collectives == ("all_to_all", "all_to_all")
    assert p.detail == (4, 2, 8, 2)  # (P, E_local, cap, k)
    slot_bytes = 8 * 8 * 16 * 4  # E*cap rows of D fp32 per source shard
    assert p.bytes_on_wire == 2 * slot_bytes * 3  # both directions, (P-1) remote
    # the reused local plans are the §4 blocked kernels
    assert p.local_key[0] == "gather_rows_blocked"
    assert p.local_key[1] == "gather_combine_blocked"
    with pytest.raises(ValueError, match="not divisible"):
        dp.plan_dist_moe(MK4, "b", 30, 16, 8, 8, 2, jnp.float32)


# ---------------------------------------------------------------------------
# execution: sharded permute / interlace (8-fake-device child)
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("mesh_shape,axis", MESHES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_shard_permute_local_matches_oracle(mesh_shape, axis, dtype):
    mesh = make_mesh(mesh_shape)
    x = rand((8, 37, 12), dtype)  # ragged middle dim
    xs = jax.device_put(x, NamedSharding(mesh, P(axis)))
    got = dp.shard_permute(xs, (1, 0, 2), mesh=mesh, in_spec=P(axis))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(x, (1, 0, 2)))
    )
    counts = jaxpr_counts(
        lambda v: dp.shard_permute(v, (1, 0, 2), mesh=mesh, in_spec=P(axis)), x
    )
    assert counts == {"all_to_all": 0, "ppermute": 0, "all_gather": 0}


@needs_mesh
@pytest.mark.parametrize("mesh_shape,axis", MESHES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_shard_permute_all_to_all_matches_oracle(mesh_shape, axis, dtype):
    mesh = make_mesh(mesh_shape)
    x = rand((8, 37, 12), dtype)
    xs = jax.device_put(x, NamedSharding(mesh, P(axis)))
    out_spec = P(None, None, axis)
    got = dp.shard_permute(xs, (1, 0, 2), mesh=mesh, in_spec=P(axis), out_spec=out_spec)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(x, (1, 0, 2)))
    )
    counts = jaxpr_counts(
        lambda v: dp.shard_permute(
            v, (1, 0, 2), mesh=mesh, in_spec=P(axis), out_spec=out_spec
        ),
        x,
    )
    assert counts["all_to_all"] == 1 and counts["all_gather"] == 0


@needs_mesh
def test_shard_permute_zero_size_shards():
    mesh = make_mesh((1, 4))
    x = jnp.zeros((8, 0, 4), jnp.float32)
    got = dp.shard_permute(
        x, (2, 1, 0), mesh=mesh, in_spec=P("b"), out_spec=P(None, None, "b")
    )
    assert got.shape == (4, 0, 8)


@needs_mesh
def test_shard_permute_replicate_fallback_matches_oracle():
    mesh = make_mesh((2, 4))
    x = rand((8, 10, 12), jnp.float32)
    # cross-axis reshard b -> a: replicate fallback (gather, permute, slice)
    got = dp.shard_permute(
        x, (1, 0, 2), mesh=mesh, in_spec=P("b"), out_spec=P(None, None, "a")
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(x, (1, 0, 2)))
    )
    counts = jaxpr_counts(
        lambda v: dp.shard_permute(
            v, (1, 0, 2), mesh=mesh, in_spec=P("b"), out_spec=P(None, None, "a")
        ),
        x,
    )
    assert counts["all_gather"] == 1 and counts["all_to_all"] == 0


@needs_mesh
def test_shard_permute_multiaxis_local_and_replicate_match_oracle():
    mesh = make_mesh((2, 4))
    x = jnp.asarray(np.arange(16 * 6 * 12).reshape(16, 6, 12), jnp.float32)
    want = np.asarray(jnp.transpose(x, (1, 0, 2)))
    xs = jax.device_put(x, NamedSharding(mesh, P(("a", "b"))))
    got = dp.shard_permute(xs, (1, 0, 2), mesh=mesh, in_spec=P(("a", "b")))
    np.testing.assert_array_equal(np.asarray(got), want)  # comm-free
    got = dp.shard_permute(
        xs, (1, 0, 2), mesh=mesh, in_spec=P(("a", "b")),
        out_spec=P(None, None, None),
    )
    np.testing.assert_array_equal(np.asarray(got), want)  # gather order


@needs_mesh
@pytest.mark.parametrize("spec", [P("b"), P(None, "b")])
def test_shard_interlace_matches_oracle(spec):
    from repro.kernels import ref

    mesh = make_mesh((1, 4))
    arrays = [rand((8, 16), jnp.float32) for _ in range(3)]
    sharded = [jax.device_put(a, NamedSharding(mesh, spec)) for a in arrays]
    got = dp.shard_interlace(sharded, mesh=mesh, spec=spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.interlace(arrays)))
    counts = jaxpr_counts(
        lambda *vs: dp.shard_interlace(list(vs), mesh=mesh, spec=spec), *arrays
    )
    assert counts == {"all_to_all": 0, "ppermute": 0, "all_gather": 0}


@needs_mesh
def test_shard_permute_interpret_runs_plan_kernels(pallas_interpret):
    mesh = make_mesh((1, 4))
    x = rand((8, 37, 12), jnp.bfloat16)
    got = dp.shard_permute(
        x, (1, 0, 2), mesh=mesh, in_spec=P("b"), out_spec=P(None, None, "b")
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(x, (1, 0, 2)))
    )


# ---------------------------------------------------------------------------
# execution: halo-exchanged stencil programs
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("mesh_shape,axis", MESHES)
@pytest.mark.parametrize("boundary", st.BOUNDARIES)
def test_halo_stencil_bit_identical(mesh_shape, axis, boundary):
    mesh = make_mesh(mesh_shape)
    x = rand((32, 18), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P(axis, None)))
    prog = JACOBI.repeat(6)
    want = prog(x, boundary=boundary)
    got = prog.shard(xs, mesh=mesh, axis=axis, boundary=boundary)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs_mesh
@pytest.mark.parametrize("dtype", DTYPES)
def test_halo_stencil_multiblock_ppermute_pairs(dtype):
    mesh = make_mesh((1, 4))
    x = rand((32, 18), dtype)
    prog = JACOBI.repeat(12)  # Hl=8 -> two k-blocks (8+4 stages)
    want = prog(x, boundary="zero")
    got = prog.shard(x, mesh=mesh, axis="b", boundary="zero")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    plan = dp.plan_dist_stencil(
        dp.mesh_key(mesh), "b", x.shape, x.dtype, prog.stages, "zero"
    )
    counts = jaxpr_counts(lambda v: prog.shard(v, mesh=mesh, axis="b"), x)
    assert counts["ppermute"] == len(plan.collectives) == 4  # one pair per block


@needs_mesh
def test_halo_stencil_mixed_radius_program():
    mesh = make_mesh((1, 4))
    x = rand((32, 18), jnp.float32)
    prog = st.box_blur(1).then(st.fd_laplacian(2)).repeat(2)  # radii 1,2,1,2
    want = prog(x, boundary="nearest")
    got = prog.shard(x, mesh=mesh, axis="b", boundary="nearest")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs_mesh
def test_halo_stencil_replicate_fallback_bit_identical():
    mesh = make_mesh((8,))
    x = rand((16, 18), jnp.float32)  # Hl=2 < radius 3
    prog = st.fd_laplacian(3).as_program()
    want = prog(x, boundary="reflect")
    got = prog.shard(x, mesh=mesh, axis="x", boundary="reflect")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs_mesh
def test_halo_stencil_zero_size():
    mesh = make_mesh((1, 4))
    x = jnp.zeros((32, 0), jnp.float32)
    assert JACOBI.repeat(2).shard(x, mesh=mesh, axis="b").shape == (32, 0)


@needs_mesh
def test_halo_stencil_interpret_fused_kernels(pallas_interpret):
    mesh = make_mesh((1, 4))
    x = rand((32, 18), jnp.float32)
    prog = JACOBI.repeat(6)
    for boundary in st.BOUNDARIES:
        want = prog(x, boundary=boundary)
        got = prog.shard(x, mesh=mesh, axis="b", boundary=boundary)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# execution: expert-parallel MoE
# ---------------------------------------------------------------------------


def _moe_setup():
    from repro import configs
    from repro.models import moe

    cfg = configs.get_config("deepseek-moe-16b-smoke")
    p = moe.moe_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(
        jax.random.PRNGKey(4), (2, 16, cfg.d_model), jnp.float32
    ).astype(cfg.np_dtype)
    return moe, cfg, p, x


@needs_mesh
@pytest.mark.parametrize("mesh_shape,axis", MESHES)
def test_moe_ep_bit_identical_to_dropless_sort(mesh_shape, axis):
    moe, cfg, p, x = _moe_setup()
    mesh = make_mesh(mesh_shape)
    psz = int(mesh.shape[axis])
    t = x.shape[0] * x.shape[1]
    want, aux_want = moe.moe_sort(p, cfg, x, capacity=t)  # dropless
    got, aux_got = moe.moe_sort_ep(p, cfg, x, mesh=mesh, axis=axis, capacity=t // psz)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.allclose(float(aux_want), float(aux_got), rtol=1e-5)


@needs_mesh
def test_moe_ep_one_all_to_all_per_direction():
    moe, cfg, p, x = _moe_setup()
    mesh = make_mesh((1, 4))
    counts = jaxpr_counts(
        lambda v: moe.moe_sort_ep(p, cfg, v, mesh=mesh, axis="b", capacity=8)[0], x
    )
    # dispatch out + combine return: exactly one all_to_all each way, and
    # no gathered-intermediate materialization (no all_gather)
    assert counts["all_to_all"] == 2 and counts["all_gather"] == 0
    plan = dp.plan_dist_moe(
        dp.mesh_key(mesh), "b", 32, cfg.d_model, cfg.moe.n_experts, 8,
        cfg.moe.top_k, x.dtype,
    )
    assert counts["all_to_all"] == len(plan.collectives)


@needs_mesh
def test_moe_ep_plan_cache_hits_across_calls():
    moe, cfg, p, x = _moe_setup()
    mesh = make_mesh((1, 4))
    moe.moe_sort_ep(p, cfg, x, mesh=mesh, axis="b", capacity=8)
    before = dp.dist_plan_cache_info()["moe"].hits
    moe.moe_sort_ep(p, cfg, x, mesh=mesh, axis="b", capacity=8)
    assert dp.dist_plan_cache_info()["moe"].hits > before


@needs_mesh
def test_moe_ep_interpret_blocked_kernels(pallas_interpret):
    moe, cfg, p, x = _moe_setup()
    mesh = make_mesh((1, 4))
    want, _ = moe.moe_sort(p, cfg, x, capacity=32)
    got, _ = moe.moe_sort_ep(p, cfg, x, mesh=mesh, axis="b", capacity=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs_mesh
@pytest.mark.parametrize("dtype", DTYPES)
def test_tuned_dist_plans_bit_identical(monkeypatch, dtype):
    """§11 autotuning on the dist engine: the tuner may swap strategies
    (all_to_all vs replicate, halo vs replicate), but every strategy is
    movement-only, so tuned execution stays bit-identical to untuned."""
    monkeypatch.setenv("REPRO_TUNE", "off")
    mesh = make_mesh((1, 4))
    x = rand((8, 37, 12), dtype)
    xs = jax.device_put(x, NamedSharding(mesh, P("b")))
    out_spec = P(None, None, "b")
    want = dp.shard_permute(
        xs, (1, 0, 2), mesh=mesh, in_spec=P("b"), out_spec=out_spec
    )
    got = dp.shard_permute(
        xs, (1, 0, 2), mesh=mesh, in_spec=P("b"), out_spec=out_spec, tuned=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    g = rand((32, 18), dtype)
    gs = jax.device_put(g, NamedSharding(mesh, P("b", None)))
    prog = JACOBI.repeat(6)
    want_s = prog(g, boundary="zero")
    got_s = dp.shard_stencil(
        prog, gs, mesh=mesh, axis="b", boundary="zero", tuned=True
    )
    tuned_plan = dp.plan_dist_stencil(
        dp.mesh_key(mesh), "b", g.shape, g.dtype, prog.stages, "zero", tuned=True
    )
    assert tuned_plan.strategy in ("halo", "replicate")
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


@needs_mesh
def test_blockwise_train_loss_matches_monolithic_on_mesh():
    """Blockwise-parallel training blocks (DESIGN.md §13) under the 2x4
    data/model mesh: the q/seq-chunked model's loss and grads match the
    monolithic model's with a data-sharded batch — chunking composes with
    SPMD sharding (chunks slice the sequence axis, which stays
    replicated)."""
    from repro import configs
    from repro.launch.mesh import make_mesh_compat, set_mesh_compat
    from repro.models import transformer as tf

    mesh = make_mesh_compat((2, 4), ("data", "model"))
    cfg = configs.get_config("qwen2-7b-smoke").with_(
        dtype="float32", n_layers=2
    )
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    sh = NamedSharding(mesh, P("data", None))
    tok = jax.device_put(jax.random.randint(k1, (8, 64), 0, cfg.vocab), sh)
    lab = jax.device_put(jax.random.randint(k2, (8, 64), 0, cfg.vocab), sh)

    def lossg(c):
        return jax.value_and_grad(lambda p: tf.loss_fn(p, c, tok, lab))(params)

    with set_mesh_compat(mesh):
        l_mono, g_mono = lossg(cfg)
        l_bw, g_bw = lossg(
            cfg.with_(blockwise=True, blockwise_chunk=32,
                      remat_policy="dots_saveable")
        )
    assert float(l_mono) == float(l_bw)
    maxdiff = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(g_mono), jax.tree.leaves(g_bw))
    )
    assert maxdiff < 1e-6


# ---------------------------------------------------------------------------
# the launcher: run the whole file on 8 forced host devices
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.skipif(_CHILD, reason="already inside the 8-device child")
def test_dist_suite_on_8_fake_devices():
    """Re-run this module in a subprocess with 8 forced host devices (the
    ``make test-dist`` configuration) so every execution test above runs."""
    from repro.launch.mesh import fake_device_env

    env = {
        **os.environ,
        **fake_device_env(8),
        "REPRO_DIST_CHILD": "1",
        "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1500,
    )
    assert r.returncode == 0, (r.stdout[-4000:] + "\n" + r.stderr[-2000:])
