"""Fused flash attention (Pallas TPU) — hillclimb #1 in EXPERIMENTS §Perf.

Why this kernel exists: the pure-JAX chunked attention in
``models.attention`` is *algorithmically* flash (online softmax, O(S)
memory), but XLA materializes each (Sq, chunk) logits tile to HBM between
the two dots.  At qwen2 train_4k scale that is ~30 GB of HBM traffic per
layer per device — the memory roofline term is 5x the compute term.  The
fused kernel keeps the logits tile in VMEM: HBM traffic drops to the
Q/K/V/O streams, which is what the (8,128)-tiled DMA schedule below moves
and *nothing else*.

Layout: grid (BH, nQ, nK), K innermost with VMEM scratch (m, l, acc)
carried across K steps; out written on the last K step.  GQA is handled
by the q-index -> kv-index map (bh // group).  Causal masking is applied
per-tile from program ids; fully-masked tiles short-circuit via pl.when.

``dma_bytes()`` reports the kernel's exact HBM traffic from its grid x
BlockSpec schedule — the roofline accounting used for the §Perf 'after'
numbers (deterministic, not estimated).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import cdiv, force_interpret, round_up

NEG_INF = -1e30


def _flash_kernel(
    nk: int, bq: int, bk: int, causal: bool, q_offset: int, skv: int,
    q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    needed = (not causal) or (ik * bk <= q_offset + iq * bq + bq - 1)

    @pl.when(needed)
    def compute():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        valid = k_pos < skv
        if causal:
            valid = valid & (q_pos >= k_pos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        # zero OOB value rows: the final partial K tile reads padded HBM
        # rows whose contents are unspecified (0 * NaN would poison acc)
        v_rows = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        v_clean = jnp.where(v_rows < skv, v_ref[0], jnp.zeros((), v_ref.dtype))
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_clean.dtype), v_clean, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)
        lse_ref[0] = m_ref[:, 0] + jnp.log(jnp.maximum(l_ref[:, 0], 1e-30))


def _flash_call(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool, q_offset: int, block_q: int, block_k: int, interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    """Raw rectangular-grid forward: (out, lse) with lse = m + log(l), the
    per-row softmax normalizer the recompute backward needs (fp32,
    (B, Hq, Sq))."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if 0 in (b, hq, sq, skv, d):
        return jnp.zeros_like(q), jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = cdiv(sq, bq), cdiv(skv, bk)

    q3 = q.reshape(b * hq, sq, d)
    k3 = k.reshape(b * hkv, skv, d)
    v3 = v.reshape(b * hkv, skv, d)

    def kv_index(bh, iq, ik):
        return (bh // g, ik, 0)

    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, nk, bq, bk, causal, q_offset, skv),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bq), lambda bh, iq, ik: (bh, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b * hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, hq, sq, d), lse.reshape(b, hq, sq)


def _ref_o_lse(q, k, v, causal, q_offset):
    """jnp (o, lse) reference — the jvp fallback for higher-order AD
    through the forward residuals.  Materializes s x s; only reachable
    when the *forward pallas call itself* is being differentiated (e.g.
    ``check_grads(order=2)`` rev-over-rev), never on the training path."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if 0 in (b, hq, sq, skv, d):
        return jnp.zeros_like(q), jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=1) if g > 1 else k
    vv = jnp.repeat(v, g, axis=1) if g > 1 else v
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    )
    if causal:
        q_pos = q_offset + jnp.arange(sq)[:, None]
        k_pos = jnp.arange(skv)[None, :]
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, axis=-1),
                   vv.astype(jnp.float32))
    return o.astype(q.dtype), lse


@functools.partial(jax.custom_jvp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_call_d(q, k, v, tri, causal, q_offset, block_q, block_k, interpret):
    """(o, lse) through the Pallas forward, jvp-able: tangents fall back
    to :func:`_ref_o_lse` so rev-over-rev AD never needs a pallas jvp."""
    if tri:
        return _flash_tri_call(q, k, v, block_q, block_k, interpret)
    return _flash_call(q, k, v, causal, q_offset, block_q, block_k, interpret)


@_flash_call_d.defjvp
def _flash_call_d_jvp(tri, causal, q_offset, block_q, block_k, interpret,
                      primals, tangents):
    q, k, v = primals
    out = _flash_call_d(q, k, v, tri, causal, q_offset, block_q, block_k,
                        interpret)
    _, t = jax.jvp(
        lambda a, b2, c: _ref_o_lse(a, b2, c, causal, q_offset),
        primals, tangents,
    )
    return out, t


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, q_offset, block_q, block_k, interpret):
    return _flash_call_d(q, k, v, False, causal, q_offset, block_q, block_k,
                         interpret)[0]


def _flash_vjp_fwd(q, k, v, causal, q_offset, block_q, block_k, interpret):
    o, lse = _flash_call_d(q, k, v, False, causal, q_offset, block_q, block_k,
                           interpret)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(causal, q_offset, block_q, block_k, interpret, res, do):
    # backward tile is planned independently of the forward tile
    # (plan_flash_bwd, DESIGN.md §11/§13) — pass None through.
    q, k, v, o, lse = res
    return _flash_bwd(q, k, v, o, lse, do, causal, q_offset, None, None, interpret)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked online-softmax attention over decode-layout (B, H, S, D)
    tensors, GQA-aware (Hq a multiple of Hkv); out = softmax(qk^T)v with
    optional causal masking (callers pre-scale q by 1/sqrt(d)).

    ``q_offset`` is the absolute position of q row 0 relative to k for the
    causal mask — the blockwise training path (DESIGN.md §13) runs each
    query chunk at its own static offset.  Differentiable: a custom VJP
    recomputes the probability tiles from (q, k, lse) in the Pallas
    backward kernels (:func:`flash_attention_bwd`), so no (Sq, Skv)
    attention matrix is ever materialized in either direction.
    """
    interpret = force_interpret() if interpret is None else interpret
    return _flash_vjp(q, k, v, causal, q_offset, block_q, block_k, interpret)


def _flash_tri_call(
    q: jax.Array, k: jax.Array, v: jax.Array,
    block_q: int, block_k: int, interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    """Raw triangular-grid forward returning (out, lse)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if sq != skv:
        raise ValueError("triangular grid needs Sq == Skv")
    if 0 in (b, hq, sq, d):
        return jnp.zeros_like(q), jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if bq != bk:
        bq = bk = min(bq, bk)
    nq = cdiv(sq, bq)
    ntiles = nq * (nq + 1) // 2

    # lower-triangle walk, row-major: (0,0),(1,0),(1,1),(2,0)...
    iq_tab, ik_tab = [], []
    for i in range(nq):
        for j in range(i + 1):
            iq_tab.append(i)
            ik_tab.append(j)
    tables = jnp.array([iq_tab, ik_tab], jnp.int32)  # (2, ntiles)

    q3 = q.reshape(b * hq, sq, d)
    k3 = k.reshape(b * hkv, skv, d)
    v3 = v.reshape(b * hkv, skv, d)

    def kernel(tab_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref):
        t = pl.program_id(1)
        iq = tab_ref[0, t]
        ik = tab_ref[1, t]

        @pl.when(ik == 0)
        def init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        qv = q_ref[0]
        kv = k_ref[0]
        s = jax.lax.dot_general(
            qv, kv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = (q_pos >= k_pos) & (k_pos < skv)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v_rows = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        v_clean = jnp.where(v_rows < skv, v_ref[0], jnp.zeros((), v_ref.dtype))
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_clean.dtype), v_clean, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(ik == iq)  # last tile of this q row
        def finalize():
            o_ref[0] = (
                acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
            ).astype(o_ref.dtype)
            lse_ref[0] = m_ref[:, 0] + jnp.log(jnp.maximum(l_ref[:, 0], 1e-30))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hq, ntiles),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, t, tab: (bh, tab[0, t], 0)),
            pl.BlockSpec((1, bk, d), lambda bh, t, tab: (bh // g, tab[1, t], 0)),
            pl.BlockSpec((1, bk, d), lambda bh, t, tab: (bh // g, tab[1, t], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, t, tab: (bh, tab[0, t], 0)),
            pl.BlockSpec((1, bq), lambda bh, t, tab: (bh, tab[0, t])),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b * hq, sq), jnp.float32),
        ],
        interpret=interpret,
    )(tables, q3, k3, v3)
    return out.reshape(b, hq, sq, d), lse.reshape(b, hq, sq)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_tri_vjp(q, k, v, block_q, block_k, interpret):
    return _flash_call_d(q, k, v, True, True, 0, block_q, block_k, interpret)[0]


def _flash_tri_vjp_fwd(q, k, v, block_q, block_k, interpret):
    o, lse = _flash_call_d(q, k, v, True, True, 0, block_q, block_k, interpret)
    return o, (q, k, v, o, lse)


def _flash_tri_vjp_bwd(block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    return _flash_bwd(q, k, v, o, lse, do, True, 0, None, None, interpret)


_flash_tri_vjp.defvjp(_flash_tri_vjp_fwd, _flash_tri_vjp_bwd)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_attention_triangular(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal flash with a *triangular* grid: only the nq(nq+1)/2
    lower-triangle (iq, ik) tiles are visited, so K/V DMA traffic halves
    vs the rectangular grid.  The (iq, ik) coordinates per grid step come
    from scalar-prefetched index tables — the same constant-memory
    analogue the paper uses for reorder strides (§III-B).  Requires
    Sq == Skv (self-attention).  Differentiable via the same recompute
    backward kernels as :func:`flash_attention` (the backward grid is
    rectangular with causal short-circuit — its upper-triangle tiles cost
    one predicated-off grid step each)."""
    interpret = force_interpret() if interpret is None else interpret
    return _flash_tri_vjp(q, k, v, block_q, block_k, interpret)


def dma_bytes(
    b: int, hq: int, hkv: int, sq: int, skv: int, d: int, itemsize: int,
    *, block_q: int = 512, block_k: int = 512, causal: bool = True,
) -> int:
    """Exact HBM traffic of the kernel from its grid x BlockSpec schedule:
    Q loaded once per (iq, ik) visit, K/V once per visit, O once per iq.
    With causal skipping, ~half the (iq, ik) tiles load K/V only to be
    skipped — the Pallas pipeline still DMAs mapped blocks, so we count
    them (upper bound; a triangle-remapped index map would halve this)."""
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = cdiv(sq, bq), cdiv(skv, bk)
    q_bytes = b * hq * nq * nk * bq * d * itemsize
    kv_bytes = 2 * b * hq * nq * nk * bk * d * itemsize  # via the bh//g map
    o_bytes = b * hq * nq * bq * d * itemsize
    return q_bytes + kv_bytes + o_bytes


# ---------------------------------------------------------------------------
# flash backward pass (training hot path, DESIGN.md §13)
#
# Recompute-based: the forward saves only (o, lse); each backward tile
# rebuilds its probability block p = exp(s - lse) from (q, k) in VMEM, so
# the (Sq, Skv) matrix never exists in HBM in either direction.  Two
# kernels with transposed grids share the recompute:
#
#   dq  grid (BH, nQ, nK), K innermost: dq_iq = sum_ik ds.k     (row carry)
#   dkv grid (BH, nK, nQ), Q innermost: dk_ik = sum_iq ds^T.q,
#                                       dv_ik = sum_iq p^T.do   (col carry)
#
# with ds = p * (do.v^T - delta), delta = rowsum(do * o) (precomputed in
# fp32 outside the kernels — O(S.D) elementwise, no s x s).  GQA: dk/dv
# are produced per *query* head and group-summed outside — an output block
# indexed bh//g would be revisited across non-adjacent grid steps, which
# the Pallas output-accumulation contract forbids.
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(
    nk: int, bq: int, bk: int, causal: bool, q_offset: int, sq: int, skv: int,
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc_ref,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    needed = (not causal) or (ik * bk <= q_offset + iq * bq + bq - 1)

    @pl.when(needed)
    def compute():
        # zero every OOB row before the dots: partial-tile HBM padding is
        # unspecified and 0 * NaN would poison the accumulators
        q_rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        k_rows = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        q = jnp.where(q_rows < sq, q_ref[0], jnp.zeros((), q_ref.dtype))
        do = jnp.where(q_rows < sq, do_ref[0], jnp.zeros((), do_ref.dtype))
        k = jnp.where(k_rows < skv, k_ref[0], jnp.zeros((), k_ref.dtype))
        v = jnp.where(k_rows < skv, v_ref[0], jnp.zeros((), v_ref.dtype))
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = (q_idx < sq) & (k_pos < skv)
        if causal:
            valid = valid & (q_offset + q_idx >= k_pos)
        lse = lse_ref[0]  # (bq,) fp32
        p = jnp.where(valid, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        delta = jnp.where(q_rows[:, 0] < sq, delta_ref[0], 0.0)  # (bq,)
        ds = p * (dp - delta[:, None])
        acc_ref[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def finalize():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(
    nq: int, bq: int, bk: int, causal: bool, q_offset: int, sq: int, skv: int,
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
):
    ik = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    needed = (not causal) or (q_offset + iq * bq + bq - 1 >= ik * bk)

    @pl.when(needed)
    def compute():
        q_rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        k_rows = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        q = jnp.where(q_rows < sq, q_ref[0], jnp.zeros((), q_ref.dtype))
        do = jnp.where(q_rows < sq, do_ref[0], jnp.zeros((), do_ref.dtype))
        k = jnp.where(k_rows < skv, k_ref[0], jnp.zeros((), k_ref.dtype))
        v = jnp.where(k_rows < skv, v_ref[0], jnp.zeros((), v_ref.dtype))
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = (q_idx < sq) & (k_pos < skv)
        if causal:
            valid = valid & (q_offset + q_idx >= k_pos)
        lse = lse_ref[0]
        p = jnp.where(valid, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        delta = jnp.where(q_rows[:, 0] < sq, delta_ref[0], 0.0)
        ds = p * (dp - delta[:, None])
        # contract over the q rows (axis 0 of both operands) -> (bk, d)
        dv_acc[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dk_acc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(iq == nq - 1)
    def finalize():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention_bwd(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    o: jax.Array,  # forward output (B, Hq, Sq, D)
    lse: jax.Array,  # forward log-sum-exp (B, Hq, Sq) fp32
    do: jax.Array,  # output cotangent (B, Hq, Sq, D)
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Recompute-based flash backward: (dq, dk, dv) from the forward
    residuals (o, lse) in two Pallas kernels with transposed grids.

    Tile geometry (``block_q`` x ``block_k``) defaults to the
    :func:`plan_flash_bwd` plan — heuristic or autotuned per ``REPRO_TUNE``
    exactly like the split-KV decode tile (DESIGN.md §11/§13).  GQA dk/dv
    are accumulated per query head in fp32 and group-summed outside the
    kernels (a ``bh // g`` output block would be revisited across
    non-adjacent grid steps).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if 0 in (b, hq, sq, skv, d):
        return jnp.zeros_like(q), jnp.zeros_like(k), jnp.zeros_like(v)
    g = hq // hkv
    if block_q is None or block_k is None:
        plan = plan_flash_bwd(b, hq, hkv, sq, skv, d, q.dtype, causal=causal)
        block_q = plan.block_q if block_q is None else block_q
        block_k = plan.block_k if block_k is None else block_k
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = cdiv(sq, bq), cdiv(skv, bk)
    interpret = force_interpret() if interpret is None else interpret

    q3 = q.reshape(b * hq, sq, d)
    k3 = k.reshape(b * hkv, skv, d)
    v3 = v.reshape(b * hkv, skv, d)
    do3 = do.reshape(b * hq, sq, d)
    lse2 = lse.reshape(b * hq, sq)
    # delta = rowsum(do * o): O(S.D) elementwise in fp32, never s x s
    delta2 = (
        (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)
    ).reshape(b * hq, sq)

    dq3 = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, nk, bq, bk, causal, q_offset, sq, skv
        ),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bq), lambda bh, iq, ik: (bh, iq)),
            pl.BlockSpec((1, bq), lambda bh, iq, ik: (bh, iq)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q3, k3, v3, do3, lse2, delta2)

    dkh, dvh = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, nq, bq, bk, causal, q_offset, sq, skv
        ),
        grid=(b * hq, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, ik, iq: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ik, iq: (bh // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ik, iq: (bh // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda bh, ik, iq: (bh, iq, 0)),
            pl.BlockSpec((1, bq), lambda bh, ik, iq: (bh, iq)),
            pl.BlockSpec((1, bq), lambda bh, ik, iq: (bh, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda bh, ik, iq: (bh, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ik, iq: (bh, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, skv, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hq, skv, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3, do3, lse2, delta2)

    dq = dq3.reshape(b, hq, sq, d)
    dk = dkh.reshape(b, hkv, g, skv, d).sum(axis=2).astype(k.dtype)
    dv = dvh.reshape(b, hkv, g, skv, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _flash_bwd(q, k, v, o, lse, do, causal, q_offset, block_q, block_k, interpret):
    """The backward map as a differentiable primitive: first-order grads
    come from the Pallas kernels; differentiating *this* function (rev-
    over-rev, e.g. ``check_grads(order=2)``) falls back to the jnp
    reference VJP below, which recomputes everything from (q, k, v, do) —
    test-scale only, it materializes s x s."""
    return flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _flash_bwd_fwd(q, k, v, o, lse, do, causal, q_offset, block_q, block_k, interpret):
    out = _flash_bwd(q, k, v, o, lse, do, causal, q_offset, block_q, block_k, interpret)
    return out, (q, k, v, o, lse, do)


def _flash_bwd_bwd(causal, q_offset, block_q, block_k, interpret, res, cts):
    # Second-order cotangents via the naive ref.attention VJP-of-VJP: the
    # reference recomputes o and lse from (q, k, v) internally, so its AD
    # carries the TOTAL derivative — the o/lse residual inputs get zero
    # cotangents to avoid double counting.
    q, k, v, o, lse, do = res
    from repro.kernels import ref as _ref

    def grads(qq, kk, vv, dd):
        _, vjp = jax.vjp(
            lambda a, b2, c: _ref.attention(
                a, b2, c, causal=causal, q_offset=q_offset
            ),
            qq, kk, vv,
        )
        return vjp(dd)

    _, vjp2 = jax.vjp(grads, q, k, v, do)
    gq, gk, gv, gdo = vjp2(tuple(cts))
    return gq, gk, gv, jnp.zeros_like(o), jnp.zeros_like(lse), gdo


_flash_bwd.defvjp(_flash_bwd_fwd, _flash_bwd_bwd)


def bwd_dma_bytes(
    b: int, hq: int, hkv: int, sq: int, skv: int, d: int, itemsize: int,
    *, block_q: int = 512, block_k: int = 512, causal: bool = True,
) -> int:
    """Exact HBM traffic of the backward sweep from its grid x BlockSpec
    schedules: both kernels stream (q, do) blocks + (lse, delta) fp32 rows
    + (k, v) blocks once per (iq, ik) visit; dq is written once per
    (bh, iq) block, dk/dv once per (bh, ik) block in fp32 (group-summed
    outside); plus the delta precompute (do, o read once, delta written).
    Causal predication skips the compute of upper-triangle tiles but the
    pipeline still DMAs mapped blocks — counted, same contract as
    :func:`dma_bytes`."""
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = cdiv(sq, bq), cdiv(skv, bk)
    visits = b * hq * nq * nk
    per_visit = (
        2 * bq * d * itemsize  # q + do blocks
        + 2 * bq * 4  # lse + delta fp32 rows
        + 2 * bk * d * itemsize  # k + v blocks (via the bh//g map)
    )
    dq_out = b * hq * nq * bq * d * itemsize
    dkv_out = 2 * b * hq * nk * bk * d * 4  # per-query-head fp32 partials
    delta_pre = 2 * b * hq * sq * d * itemsize + b * hq * sq * 4
    return 2 * visits * per_visit + dq_out + dkv_out + delta_pre


@dataclass(frozen=True)
class FlashBwdPlan:
    """Cached backward tile decision for one flash-attention shape.

    Mirrors :class:`DecodePlan` (DESIGN.md §11): frozen, memoized on the
    static shape key, carrying the deterministic traffic accounting so
    benchmarks compare achieved vs predicted movement for the backward
    sweep too."""

    block_q: int  # query rows per backward tile
    block_k: int  # key rows per backward tile
    grid_dq: tuple  # (B*Hq, nQ, nK) — dq kernel, K innermost
    grid_dkv: tuple  # (B*Hq, nK, nQ) — dk/dv kernel, Q innermost
    bytes_moved: int  # both kernels + delta precompute
    roofline_s: float  # bytes / HBM bandwidth (one chip)

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        return (
            f"flash_bwd: block_q={self.block_q} block_k={self.block_k} "
            f"grid_dq={self.grid_dq} grid_dkv={self.grid_dkv} "
            f"{self.bytes_moved/1e6:.2f} MB moved, "
            f"roofline {self.roofline_s*1e6:.1f} us"
        )


def _bwd_heuristic(sq: int, skv: int) -> tuple[int, int]:
    """Default backward tile: the forward's 512-row blocks clamped to the
    sequence — big enough to amortize the per-tile recompute dot, small
    enough that (q, k, v, do) tiles + two fp32 accumulators fit VMEM."""
    return min(512, round_up(sq, 8)), min(512, round_up(skv, 8))


def _bwd_candidates(b, hq, hkv, sq, skv, d, itemsize, causal):
    """The backward search space: the heuristic (block_q, block_k) tile
    first (tie-break contract), then the half/double neighbors."""
    from repro.core import tune
    from repro.utils.roofline import movement_cost_s

    base_bq, base_bk = _bwd_heuristic(sq, skv)
    pairs = [(base_bq, base_bk)]
    for bq in (base_bq // 2, base_bq, base_bq * 2):
        for bk in (base_bk // 2, base_bk, base_bk * 2):
            bq_c = max(8, min(round_up(bq, 8), round_up(sq, 8)))
            bk_c = max(8, min(round_up(bk, 8), round_up(skv, 8)))
            if (bq_c, bk_c) not in pairs:
                pairs.append((bq_c, bk_c))
    cands = []
    for bq, bk in pairs:
        steps = 2 * b * hq * cdiv(sq, min(bq, sq)) * cdiv(skv, min(bk, skv))
        cands.append(
            tune.Candidate(
                label=f"bq{bq}_bk{bk}",
                params=(("block_q", bq), ("block_k", bk)),
                cost_s=movement_cost_s(
                    bwd_dma_bytes(
                        b, hq, hkv, sq, skv, d, itemsize,
                        block_q=bq, block_k=bk, causal=causal,
                    ),
                    steps,
                ),
            )
        )
    return cands


def _bwd_runner_factory(b, hq, hkv, sq, skv, d, dtype_name, causal):
    """Measured-mode runner: execute one candidate backward tile on
    deterministic sample tensors (forward residuals computed once)."""

    def factory(cand):
        from repro.core import tune

        p = cand.param_dict()
        q = tune.sample_array((b, hq, sq, d), dtype_name)
        k = tune.sample_array((b, hkv, skv, d), dtype_name)
        v = tune.sample_array((b, hkv, skv, d), dtype_name)
        do = tune.sample_array((b, hq, sq, d), dtype_name)
        interp = jax.default_backend() != "tpu"
        o, lse = _flash_call(q, k, v, causal, 0, 512, 512, interp)
        fn = jax.jit(
            lambda q, k, v, o, lse, do: flash_attention_bwd(
                q, k, v, o, lse, do, causal=causal,
                block_q=p["block_q"], block_k=p["block_k"],
            )
        )
        return lambda: fn(q, k, v, o, lse, do)

    return factory


def _bwd_mk(b, hq, hkv, sq, skv, d, dtype_name, causal, bq, bk) -> FlashBwdPlan:
    itemsize = jnp.dtype(dtype_name).itemsize
    bq = min(bq, round_up(sq, 8))
    bk = min(bk, round_up(skv, 8))
    nq, nk = cdiv(sq, min(bq, sq)), cdiv(skv, min(bk, skv))
    bytes_moved = bwd_dma_bytes(
        b, hq, hkv, sq, skv, d, itemsize, block_q=bq, block_k=bk, causal=causal
    )
    from repro.core.plan import HBM_GBPS

    return FlashBwdPlan(
        block_q=bq,
        block_k=bk,
        grid_dq=(b * hq, nq, nk),
        grid_dkv=(b * hq, nk, nq),
        bytes_moved=bytes_moved,
        roofline_s=bytes_moved / (HBM_GBPS * 1e9),
    )


@functools.lru_cache(maxsize=1024)
def _bwd_plan_cached(
    b: int, hq: int, hkv: int, sq: int, skv: int, d: int,
    dtype_name: str, causal: bool,
) -> FlashBwdPlan:
    bq, bk = _bwd_heuristic(sq, skv)
    return _bwd_mk(b, hq, hkv, sq, skv, d, dtype_name, causal, bq, bk)


@functools.lru_cache(maxsize=1024)
def _bwd_plan_tuned_cached(
    b: int, hq: int, hkv: int, sq: int, skv: int, d: int,
    dtype_name: str, causal: bool, mode: str,
) -> FlashBwdPlan:
    from repro.core import tune

    base = _bwd_plan_cached(b, hq, hkv, sq, skv, d, dtype_name, causal)
    itemsize = jnp.dtype(dtype_name).itemsize
    choice = tune.select(
        "flash_bwd",
        f"b={b}|hq={hq}|hkv={hkv}|sq={sq}|skv={skv}|d={d}"
        f"|dtype={dtype_name}|causal={int(causal)}",
        _bwd_candidates(b, hq, hkv, sq, skv, d, itemsize, causal),
        _bwd_runner_factory(b, hq, hkv, sq, skv, d, dtype_name, causal),
        mode=mode,
    )
    p = choice.param_dict()
    if (p["block_q"], p["block_k"]) == (base.block_q, base.block_k):
        return base  # heuristic won: tuned plan IS the untuned plan object
    return _bwd_mk(
        b, hq, hkv, sq, skv, d, dtype_name, causal, p["block_q"], p["block_k"]
    )


def plan_flash_bwd(
    b: int, hq: int, hkv: int, sq: int, skv: int, d: int, dtype,
    *, causal: bool = True, tuned: bool | None = None,
) -> FlashBwdPlan:
    """Plan (and cache) the flash backward tile for one attention shape.

    ``tuned=None`` resolves from ``REPRO_TUNE`` like every other plan
    engine: off -> the deterministic heuristic; on -> the (block_q,
    block_k) neighborhood is measured on TPU or cost-scored elsewhere via
    ``core.tune.select`` with the same lru identity guarantees (repeated
    calls return the *identical* plan object).

    Example::

        plan = plan_flash_bwd(8, 32, 8, 4096, 4096, 128, jnp.bfloat16)
        print(plan.describe())
    """
    from repro.core import tune

    if tuned is None:
        tuned = tune.tune_default()
    key = (
        int(b), int(hq), int(hkv), int(sq), int(skv), int(d),
        jnp.dtype(dtype).name, bool(causal),
    )
    if not tuned:
        return _bwd_plan_cached(*key)
    return _bwd_plan_tuned_cached(*key, tune.resolve_mode())


# ---------------------------------------------------------------------------
# split-KV decode attention (serving hot path, DESIGN.md §12)
#
# Decode reads the whole KV ring for ONE query row per head — pure memory
# bound.  The one-shot grid serializes the S axis behind a single (m, l,
# acc) carry; the split-KV grid partitions each slot's ring into splits
# computed in parallel, each keeping its own running statistics, and a
# second single-pallas_call stage folds the per-split partials with a
# mid-softmax rescale (the `_fwd_kernel_stage2_asm` shape).  GQA packs the
# G = Hq//Hkv query heads of one KV head into the sublane axis so K/V rows
# stream from HBM once per KV head instead of once per query head.
# ---------------------------------------------------------------------------


def _decode_split_kernel(
    nks: int, bk: int, s_max: int, hkv: int,
    len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
    m_ref, l_ref, acc_ref,
):
    """Stage 1: one (KV-head, split, k-block) grid step of the partial
    online softmax; per-split (m, l, acc) land in the mid arrays."""
    bh = pl.program_id(0)
    isp = pl.program_id(1)
    ik = pl.program_id(2)
    g = q_ref.shape[1]

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = jnp.minimum(len_ref[bh // hkv], s_max)
    start = (isp * nks + ik) * bk

    @pl.when(start < length)
    def compute():
        q = q_ref[0]  # (G, d), pre-scaled
        k = k_ref[0]  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bk)
        k_pos = start + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        # zero rows past the valid length: their logits are NEG_INF so the
        # probabilities underflow to 0, but 0 * garbage must stay 0
        v_rows = start + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        v_clean = jnp.where(v_rows < length, v_ref[0], jnp.zeros((), v_ref.dtype))
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_clean.dtype), v_clean, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nks - 1)
    def finalize():
        o_ref[0, 0] = acc_ref[...]
        m_out_ref[0, 0] = m_ref[:, 0]
        l_out_ref[0, 0] = l_ref[:, 0]


def _decode_combine_kernel(ns: int, mid_o_ref, mid_m_ref, mid_l_ref, o_ref):
    """Stage 2: fold the per-split (m, l, acc) partials with a running
    mid-softmax rescale — the `_fwd_kernel_stage2_asm` recurrence."""
    g, d = o_ref.shape[1], o_ref.shape[2]
    e_max = jnp.full((g,), NEG_INF, jnp.float32)
    e_sum = jnp.zeros((g,), jnp.float32)
    acc = jnp.zeros((g, d), jnp.float32)
    for i in range(ns):
        tv = mid_o_ref[0, i]  # (G, d) unnormalized partial
        tm = mid_m_ref[0, i]  # (G,) split max
        tl = mid_l_ref[0, i]  # (G,) split exp-sum
        n_e_max = jnp.maximum(tm, e_max)
        old_scale = jnp.exp(e_max - n_e_max)
        p = jnp.exp(tm - n_e_max)
        acc = acc * old_scale[:, None] + p[:, None] * tv
        e_sum = e_sum * old_scale + p * tl
        e_max = n_e_max
    o_ref[0] = (acc / jnp.maximum(e_sum, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_splits", "interpret"))
def decode_combine(
    mid_o: jax.Array,  # (BH, ns, G, d) float32
    mid_m: jax.Array,  # (BH, ns, G) float32
    mid_l: jax.Array,  # (BH, ns, G) float32
    *,
    num_splits: int,
    interpret: bool | None = None,
) -> jax.Array:
    """The stage-2 combine as ONE ``pallas_call`` over the (BH,) grid —
    jaxpr-assertable (tests/test_serve_engine.py) and reused verbatim by
    :func:`flash_decode`.  Returns the normalized output (BH, G, d)."""
    bh, ns, g, d = mid_o.shape
    interpret = force_interpret() if interpret is None else interpret
    return pl.pallas_call(
        functools.partial(_decode_combine_kernel, num_splits),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, ns, g, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, ns, g), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, ns, g), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, d), mid_o.dtype),
        interpret=interpret,
    )(mid_o, mid_m, mid_l)


@functools.partial(
    jax.jit, static_argnames=("num_splits", "block_k", "interpret")
)
def flash_decode(
    q: jax.Array,  # (B, Hq, 1, D)
    k: jax.Array,  # (B, Hkv, S_max, D) ring buffer
    v: jax.Array,
    *,
    lengths: jax.Array,  # (B,) int32 valid rows per slot
    num_splits: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Split-KV decode attention over per-slot ring buffers.

    Each slot's KV ring is partitioned into ``num_splits`` splits computed
    in parallel (grid axis 1), each carrying its own running (m, l, acc)
    statistics; :func:`decode_combine` then folds the partials with a
    mid-softmax rescale.  ``lengths`` holds the TRUE per-slot valid-row
    counts, so a slot admitted late never attends over another slot's ring
    tail (the Engine.step position bug this kernel replaces).  Tile
    geometry (``num_splits`` x ``block_k``) defaults to the
    :func:`plan_flash_decode` plan — heuristic or autotuned per
    ``REPRO_TUNE`` (DESIGN.md §11).
    """
    b, hq, sq, d = q.shape
    _, hkv, s_max, _ = k.shape
    if sq != 1:
        raise ValueError(f"flash_decode is single-token only, got Sq={sq}")
    g = hq // hkv
    if num_splits is None or block_k is None:
        plan = plan_flash_decode(b, hq, hkv, s_max, d, q.dtype)
        num_splits = plan.num_splits if num_splits is None else num_splits
        block_k = plan.block_k if block_k is None else block_k
    bk = min(block_k, s_max)
    nkb = cdiv(s_max, bk)
    ns = max(1, min(num_splits, nkb))
    nks = cdiv(nkb, ns)  # k blocks per split
    ns = cdiv(nkb, nks)  # splits actually visited
    s_pad = ns * nks * bk
    if s_pad != s_max:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad - s_max), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad - s_max), (0, 0)))

    q3 = (q * (d ** -0.5)).reshape(b * hkv, g, d)
    k3 = k.reshape(b * hkv, s_pad, d)
    v3 = v.reshape(b * hkv, s_pad, d)
    lens = jnp.minimum(
        jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (b,)), s_max
    )

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, ns, nks),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, isp, ik, lens: (bh, 0, 0)),
            pl.BlockSpec(
                (1, bk, d), lambda bh, isp, ik, lens: (bh, isp * nks + ik, 0)
            ),
            pl.BlockSpec(
                (1, bk, d), lambda bh, isp, ik, lens: (bh, isp * nks + ik, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bh, isp, ik, lens: (bh, isp, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bh, isp, ik, lens: (bh, isp, 0)),
            pl.BlockSpec((1, 1, g), lambda bh, isp, ik, lens: (bh, isp, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    mid_o, mid_m, mid_l = pl.pallas_call(
        functools.partial(_decode_split_kernel, nks, bk, s_max, hkv),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, ns, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, ns, g), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, ns, g), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q3, k3, v3)
    out = decode_combine(mid_o, mid_m, mid_l, num_splits=ns, interpret=interpret)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


@dataclass(frozen=True)
class DecodePlan:
    """Cached split-KV tile decision for one decode-attention shape.

    Mirrors the other plan engines (DESIGN.md §3/§4/§11): frozen, memoized
    on the static shape key, and carrying the deterministic traffic
    accounting so benchmarks compare achieved vs predicted movement.
    """

    num_splits: int  # parallel KV splits per slot (stage-1 grid axis)
    block_k: int  # KV rows per grid step inside a split
    grid: tuple  # (B*Hkv, num_splits, k-blocks-per-split)
    bytes_moved: int  # stage-1 + stage-2 HBM traffic
    roofline_s: float  # bytes / HBM bandwidth (one chip)

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        return (
            f"flash_decode: splits={self.num_splits} block_k={self.block_k} "
            f"grid={self.grid} {self.bytes_moved/1e6:.2f} MB moved, "
            f"roofline {self.roofline_s*1e6:.1f} us"
        )


def decode_dma_bytes(
    b: int, hq: int, hkv: int, s_max: int, d: int, itemsize: int,
    *, num_splits: int, block_k: int,
) -> int:
    """Exact HBM traffic of the two-stage split-KV schedule: K/V rows once
    per (split, k-block) visit, the G query rows re-read per grid step,
    the fp32 mid partials written by stage 1 and re-read by stage 2, and
    the final output rows."""
    g = hq // hkv
    bk = min(block_k, s_max)
    nkb = cdiv(s_max, bk)
    ns = max(1, min(num_splits, nkb))
    nks = cdiv(nkb, ns)
    ns = cdiv(nkb, nks)
    steps = b * hkv * ns * nks
    kv_bytes = 2 * steps * bk * d * itemsize
    q_bytes = steps * g * d * itemsize
    mid_bytes = 2 * b * hkv * ns * g * (d + 2) * 4  # written then re-read
    o_bytes = b * hq * d * itemsize
    return kv_bytes + q_bytes + mid_bytes + o_bytes


def _decode_candidates(b, hq, hkv, s_max, d, itemsize):
    """The split-KV search space: the heuristic (num_splits, block_k) tile
    first (tie-break contract), then the split-count and block neighbors."""
    from repro.core import tune
    from repro.utils.roofline import movement_cost_s

    base_ns, base_bk = _decode_heuristic(s_max)
    pairs = [(base_ns, base_bk)]
    for ns in (base_ns // 2, base_ns * 2, 1):
        for bk in (base_bk // 2, base_bk, base_bk * 2):
            ns_c = max(1, min(ns, cdiv(s_max, 8)))
            bk_c = max(8, min(round_up(bk, 8), round_up(s_max, 8)))
            if (ns_c, bk_c) not in pairs:
                pairs.append((ns_c, bk_c))
    cands = []
    for ns, bk in pairs:
        nkb = cdiv(s_max, bk)
        nks = cdiv(nkb, min(ns, nkb))
        ns_eff = cdiv(nkb, nks)
        steps = b * hkv * ns_eff * nks + b * hkv  # stage 1 + stage 2
        cands.append(
            tune.Candidate(
                label=f"ns{ns}_bk{bk}",
                params=(("num_splits", ns), ("block_k", bk)),
                cost_s=movement_cost_s(
                    decode_dma_bytes(
                        b, hq, hkv, s_max, d, itemsize,
                        num_splits=ns, block_k=bk,
                    ),
                    steps,
                ),
            )
        )
    return cands


def _decode_heuristic(s_max: int) -> tuple[int, int]:
    """Default tile: ~512-row splits (enough rows to amortize the per-step
    overhead) in 256-row k-blocks, clamped to the ring size."""
    bk = min(256, round_up(s_max, 8))
    ns = max(1, min(cdiv(s_max, 512), 8, cdiv(s_max, bk)))
    return ns, bk


def _decode_runner_factory(b, hq, hkv, s_max, d, dtype_name):
    """Measured-mode runner: execute one candidate tile on deterministic
    sample tensors (full-length slots — the steady-state decode shape)."""

    def factory(cand):
        from repro.core import tune

        p = cand.param_dict()
        q = tune.sample_array((b, hq, 1, d), dtype_name)
        k = tune.sample_array((b, hkv, s_max, d), dtype_name)
        v = tune.sample_array((b, hkv, s_max, d), dtype_name)
        lens = jnp.full((b,), s_max, jnp.int32)
        fn = jax.jit(
            lambda q, k, v, lens: flash_decode(
                q, k, v, lengths=lens,
                num_splits=p["num_splits"], block_k=p["block_k"],
            )
        )
        return lambda: fn(q, k, v, lens)

    return factory


@functools.lru_cache(maxsize=1024)
def _decode_plan_cached(
    b: int, hq: int, hkv: int, s_max: int, d: int, dtype_name: str
) -> DecodePlan:
    ns, bk = _decode_heuristic(s_max)
    return _decode_mk(b, hq, hkv, s_max, d, dtype_name, ns, bk)


def _decode_mk(b, hq, hkv, s_max, d, dtype_name, ns, bk) -> DecodePlan:
    itemsize = jnp.dtype(dtype_name).itemsize
    bk = min(bk, round_up(s_max, 8))
    nkb = cdiv(s_max, bk)
    ns = max(1, min(ns, nkb))
    nks = cdiv(nkb, ns)
    ns = cdiv(nkb, nks)
    bytes_moved = decode_dma_bytes(
        b, hq, hkv, s_max, d, itemsize, num_splits=ns, block_k=bk
    )
    from repro.core.plan import HBM_GBPS

    return DecodePlan(
        num_splits=ns,
        block_k=bk,
        grid=(b * hkv, ns, nks),
        bytes_moved=bytes_moved,
        roofline_s=bytes_moved / (HBM_GBPS * 1e9),
    )


@functools.lru_cache(maxsize=1024)
def _decode_plan_tuned_cached(
    b: int, hq: int, hkv: int, s_max: int, d: int, dtype_name: str, mode: str
) -> DecodePlan:
    from repro.core import tune

    base = _decode_plan_cached(b, hq, hkv, s_max, d, dtype_name)
    itemsize = jnp.dtype(dtype_name).itemsize
    choice = tune.select(
        "flash_decode",
        f"b={b}|hq={hq}|hkv={hkv}|s={s_max}|d={d}|dtype={dtype_name}",
        _decode_candidates(b, hq, hkv, s_max, d, itemsize),
        _decode_runner_factory(b, hq, hkv, s_max, d, dtype_name),
        mode=mode,
    )
    p = choice.param_dict()
    if (p["num_splits"], p["block_k"]) == (base.num_splits, base.block_k):
        return base  # heuristic won: tuned plan IS the untuned plan object
    return _decode_mk(
        b, hq, hkv, s_max, d, dtype_name, p["num_splits"], p["block_k"]
    )


def plan_flash_decode(
    b: int, hq: int, hkv: int, s_max: int, d: int, dtype,
    *, tuned: bool | None = None,
) -> DecodePlan:
    """Plan (and cache) the split-KV decode tile for one attention shape.

    ``tuned=None`` resolves from ``REPRO_TUNE`` like every other plan
    engine: off -> the deterministic heuristic; on -> the (num_splits,
    block_k) neighborhood is measured on TPU or cost-scored elsewhere via
    ``core.tune.select`` with the same lru identity guarantees (repeated
    calls return the *identical* plan object).

    Example::

        plan = plan_flash_decode(8, 32, 8, 4096, 128, jnp.bfloat16)
        print(plan.describe())
    """
    from repro.core import tune

    if tuned is None:
        tuned = tune.tune_default()
    key = (int(b), int(hq), int(hkv), int(s_max), int(d), jnp.dtype(dtype).name)
    if not tuned:
        return _decode_plan_cached(*key)
    return _decode_plan_tuned_cached(*key, tune.resolve_mode())
