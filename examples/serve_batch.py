"""Batched serving example: continuous batching over fixed decode slots.

Where each serving stage lowers through the plan engines:

* **prefill** — `split_heads`/`merge_heads` inside every attention block
  route through the rearrangement planner (`core/plan.py`, DESIGN.md §3):
  each is ONE batched-transpose kernel with the framing reshapes folded
  away; the prefill→decode cache relayout (`kv_cache_to_decode_layout`)
  is the same §3 adjacent-swap plan.
* **decode** — slot compaction when requests retire gathers live rows by
  index, i.e. the index-set engine (`core/index_plan.py`, §4): a blocked
  masked gather, with freed slots as `-1` sentinels.
* **MoE archs** — dispatch/combine is the §4 two-kernel sort path
  (`models/moe.py`); on a mesh, the expert-parallel variant
  (`moe_sort_ep`) wraps the same kernels in the §10 distributed planner.

  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import Engine, Request


def main() -> None:
    cfg = configs.get_config("recurrentgemma-2b-smoke")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, batch_slots=4, s_max=128, prompt_bucket=32)

    rng = np.random.default_rng(0)
    requests = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(8, 30))).astype(np.int32),
            max_new=12,
        )
        for i in range(10)  # 10 requests through 4 slots
    ]
    t0 = time.time()
    done = engine.run(requests)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {tokens} new tokens, {dt:.2f}s ({tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid} (prompt {len(r.prompt)} toks) -> {r.out[:6]}...")


if __name__ == "__main__":
    main()
