"""Production mesh construction (16x16 single pod / 2x16x16 multi-pod).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the dry-run's forced 512-device
initialization to happen first).
"""

from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh():
    """Degenerate mesh over whatever devices exist (smoke/e2e runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))


def mesh_axes_info(mesh) -> dict:
    names = mesh.axis_names
    return {
        "model": "model",
        "data": "data",
        "model_size": mesh.shape["model"] if "model" in names else 1,
        "data_size": mesh.shape["data"] if "data" in names else 1,
        "pod_size": mesh.shape["pod"] if "pod" in names else 1,
        "multi_pod": "pod" in names,
    }


def batch_axes(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
