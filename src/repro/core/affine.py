"""Affine index-map IR: closed-form tiling for the whole rearrangement class.

The paper (and PR 5's autotuner) pick tiles by heuristic formula and then
*measure* a neighborhood.  Bouverot-Dupuis & Sheeran (arXiv:2306.07795)
observe that every request this library lowers — reshape, permute, window,
stride, bit-reversal — is an **affine index map over mixed-radix digit
spaces**: ``in-index = A·out-index + b`` where the index vectors are digit
decompositions and A routes digits.  For that class the bandwidth-optimal
tile is derivable in closed form from the contiguity run-lengths on both
sides (the load block covers the input-fastest run, the store block the
output-fastest run), so the tuner's job collapses to *verifying* the
analytic seed's ±1 neighborhood instead of searching (DESIGN.md §14).

The IR
------
:class:`AffineMap` is the gather form: for output digit coordinates
``o[0..m-1]`` the input digit coordinates are

    c[src[j]] = base[src[j]] + ((o[j] + rot[j] + skew_sign[j] * o[skew[j]])
                                 mod out_digits[j])
    c[i]      = base[i]                    for input digits no output reads

* ``src``   — the 0/1 routing matrix A (one input digit per output digit);
* ``base``  — the offset vector b (window bases, stride phases);
* ``rot``   — per-digit modular rotation (seeded bijective shuffles,
  Mitchell et al., arXiv:2106.06161 — table-free index functions);
* ``skew``/``skew_sign`` — one cross-digit term (the paper's diagonal
  reorder: ``in_col = (i + j) mod C`` is affine over Z_C).

``compose`` / ``invert`` / ``digit_split`` close the algebra;
``merge_runs`` is the coalescing projection (the affine form of
``layout.coalesce``, asserted equivalent in tests);  :func:`derive` maps a
recognized request to its execution plane and closed-form tiles.

Everything here is static planning metadata (pure python / numpy): the
kernels receive the map as a hashable compile-time constant and turn it
into BlockSpec ``index_map`` arithmetic — zero gather tables in HBM.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

import numpy as np

from repro.kernels.tiling import (
    align_block,
    cdiv,
    plan_copy_tiles,
    plan_transpose_tiles,
    plan_transpose_vec_tiles,
)


def _prod(xs) -> int:
    return int(math.prod(xs)) if xs else 1


@dataclass(frozen=True)
class AffineMap:
    """Affine index map over mixed-radix digit spaces (gather form).

    ``out[o] = in[f(o)]`` with ``f`` as in the module docstring.  The map is
    immutable and hashable — plans cache on it and the kernels take it as a
    static (compile-time) argument.
    """

    in_digits: tuple[int, ...]
    out_digits: tuple[int, ...]
    src: tuple[int, ...]  # src[j]: input digit read by output digit j
    base: tuple[int, ...]  # per-input-digit additive offset
    rot: tuple[int, ...]  # per-output-digit modular rotation
    skew: tuple[int, ...]  # per-output-digit cross term source (-1: none)
    skew_sign: tuple[int, ...]  # +1 / -1 sign of the cross term

    def __post_init__(self):
        ni, mo = len(self.in_digits), len(self.out_digits)
        if not (len(self.src) == len(self.rot) == len(self.skew)
                == len(self.skew_sign) == mo):
            raise ValueError("out-digit field lengths disagree")
        if len(self.base) != ni:
            raise ValueError("base must have one entry per input digit")
        if any(r < 1 for r in self.in_digits + self.out_digits):
            raise ValueError("digit radices must be >= 1 (zero-size arrays "
                             "are handled by the planner, not the IR)")
        if len(set(self.src)) != mo:
            raise ValueError(f"src {self.src} is not injective")
        mapped = set()
        for j in range(mo):
            d, r = self.src[j], self.out_digits[j]
            if not 0 <= d < ni:
                raise ValueError(f"src[{j}]={d} out of range")
            mapped.add(d)
            if not (0 <= self.base[d] and self.base[d] + r <= self.in_digits[d]):
                raise ValueError(
                    f"digit {j}: window [{self.base[d]}, {self.base[d]}+{r}) "
                    f"exceeds input radix {self.in_digits[d]}"
                )
            if not 0 <= self.rot[j] < r:
                raise ValueError(f"rot[{j}]={self.rot[j]} outside [0, {r})")
            k = self.skew[j]
            if k == -1:
                if self.skew_sign[j] != 1:
                    raise ValueError("skew_sign must be +1 when skew is -1")
            else:
                if not (0 <= k < mo and k != j):
                    raise ValueError(f"skew[{j}]={k} invalid")
                if self.skew_sign[j] not in (1, -1):
                    raise ValueError("skew_sign must be +1 or -1")
                if self.rot[k] != 0 or self.skew[k] != -1:
                    raise ValueError(
                        f"skew source digit {k} must be plain (rot=0, no "
                        f"skew) so the map stays invertible"
                    )
        for i in range(ni):
            if i not in mapped and not 0 <= self.base[i] < self.in_digits[i]:
                raise ValueError(f"unmapped digit {i}: base {self.base[i]} "
                                 f"outside [0, {self.in_digits[i]})")

    # -- inspection ---------------------------------------------------------

    @property
    def n_in(self) -> int:
        """Total input index-space size."""
        return _prod(self.in_digits)

    @property
    def n_out(self) -> int:
        """Total output index-space size."""
        return _prod(self.out_digits)

    def is_bijection(self) -> bool:
        """True when the map permutes the full index space (every input
        digit mapped at full radix — ``invert`` requires this)."""
        return (
            len(self.out_digits) == len(self.in_digits)
            and set(self.src) == set(range(len(self.in_digits)))
            and all(
                self.out_digits[j] == self.in_digits[self.src[j]]
                for j in range(len(self.out_digits))
            )
        )

    def is_permutation(self) -> bool:
        """True for pure digit routing (a (shape, perm) transpose in digit
        space): bijective with no rotations and no cross terms."""
        return (
            self.is_bijection()
            and all(r == 0 for r in self.rot)
            and all(k == -1 for k in self.skew)
        )

    # -- constructors -------------------------------------------------------

    @classmethod
    def identity(cls, shape) -> "AffineMap":
        """The identity map on ``shape`` (reshape requests: the flat index
        is unchanged, only the digit grouping differs)."""
        shape = tuple(int(s) for s in shape)
        n = len(shape)
        return cls(shape, shape, tuple(range(n)), (0,) * n, (0,) * n,
                   (-1,) * n, (1,) * n)

    @classmethod
    def from_perm(cls, shape, perm) -> "AffineMap":
        """The transpose ``out = transpose(x, perm)`` as a digit routing."""
        shape = tuple(int(s) for s in shape)
        perm = tuple(int(p) for p in perm)
        if sorted(perm) != list(range(len(shape))):
            raise ValueError(f"bad perm {perm} for rank {len(shape)}")
        m = len(perm)
        return cls(shape, tuple(shape[p] for p in perm), perm,
                   (0,) * len(shape), (0,) * m, (-1,) * m, (1,) * m)

    @classmethod
    def from_window(cls, shape, base, sizes, perm) -> "AffineMap":
        """The fused windowed reorder ``transpose(x[base:base+sizes], perm)``
        (paper §III-B N->M): window bases ride in ``base``, the permute in
        ``src``."""
        shape = tuple(int(s) for s in shape)
        base = tuple(int(b) for b in base)
        sizes = tuple(int(s) for s in sizes)
        perm = tuple(int(p) for p in perm)
        m = len(perm)
        return cls(shape, tuple(sizes[p] for p in perm), perm, base,
                   (0,) * m, (-1,) * m, (1,) * m)

    # -- algebra ------------------------------------------------------------

    def digit_split(self, j: int, factors) -> "AffineMap":
        """Split output digit ``j`` (and the input digit it reads) into the
        mixed-radix ``factors`` (product must equal the radix).  Only plain
        full-radix digits split — a rotation or cross term has no digit-wise
        decomposition."""
        factors = tuple(int(f) for f in factors)
        r = self.out_digits[j]
        if _prod(factors) != r:
            raise ValueError(f"factors {factors} do not multiply to {r}")
        if self.rot[j] != 0 or self.skew[j] != -1 or j in set(self.skew):
            raise ValueError("only plain digits (no rot/skew) can split")
        d = self.src[j]
        if self.in_digits[d] != r or self.base[d] != 0:
            raise ValueError("only full-radix zero-base digits can split")
        k = len(factors)

        def shift_in(i):
            return i if i < d else i + k - 1

        in_digits = (self.in_digits[:d] + factors + self.in_digits[d + 1:])
        base = (self.base[:d] + (0,) * k + self.base[d + 1:])
        out_digits = (self.out_digits[:j] + factors + self.out_digits[j + 1:])
        src, rot, skew, sign = [], [], [], []
        for t in range(len(self.out_digits)):
            if t == j:
                src.extend(d + q for q in range(k))
                rot.extend([0] * k)
                skew.extend([-1] * k)
                sign.extend([1] * k)
            else:
                src.append(shift_in(self.src[t]))
                rot.append(self.rot[t])
                s = self.skew[t]
                skew.append(s if s < j else (s + k - 1) if s > j else s)
                sign.append(self.skew_sign[t])
        return AffineMap(in_digits, out_digits, tuple(src), base,
                         tuple(rot), tuple(skew), tuple(sign))

    def invert(self) -> "AffineMap":
        """The inverse gather map (bijections only): rotations negate, the
        cross term flips sign, ``src`` inverts."""
        if not self.is_bijection():
            raise ValueError("only full-radix bijections invert")
        n = len(self.src)
        inv_of = {self.src[j]: j for j in range(n)}  # in digit -> out digit
        src, rot, skew, sign = [], [], [], []
        for i in range(n):  # inverse out digit i == original in digit i
            j = inv_of[i]
            r = self.out_digits[j]
            src.append(j)
            rot.append((-self.rot[j]) % r)
            k = self.skew[j]
            if k == -1:
                skew.append(-1)
                sign.append(1)
            else:
                # o_j = (c_i - rot - s*o_k) mod r, and o_k = c_{src[k]}
                skew.append(self.src[k])
                sign.append(-self.skew_sign[j])
        return AffineMap(self.out_digits, self.in_digits, tuple(src),
                         (0,) * n, tuple(rot), tuple(skew), tuple(sign))

    def compose(self, g: "AffineMap") -> "AffineMap":
        """Function composition ``self ∘ g`` (apply ``g``'s gather first):
        the fused map of op ``B(A(x))`` where ``self`` is A's map and ``g``
        B's.  Requires ``g.in_digits == self.out_digits``; raises when the
        per-digit mod-affine functions do not stay representable."""
        if g.in_digits != self.out_digits:
            raise ValueError(
                f"digit spaces disagree: {g.in_digits} vs {self.out_digits}"
            )
        mo = len(g.out_digits)
        src, rot, skew, sign = [], [], [], []
        base = list(self.base)
        f_inv = {self.src[j]: j for j in range(len(self.src))}

        def f_plain(k):  # self's digit k is the identity function
            return (self.rot[k] == 0 and self.skew[k] == -1
                    and self.base[self.src[k]] == 0)

        for j in range(mo):
            k = g.src[j]  # self-out digit fed by g-out digit j
            d = self.src[k]
            rf, rg = self.out_digits[k], g.out_digits[j]
            g_base = g.base[k]
            # composed per-digit function:
            #   c = base_f[d] + ((y + rot_f + s_f*y_sk) % rf),
            #   y = g_base + ((o + rot_g + s_g*o_sk) % rg)
            if self.rot[k] == 0 and self.skew[k] == -1:
                # f translates: c = base_f[d] + g_base + ((o + ...) % rg)
                src.append(d)
                rot.append(g.rot[j])
                skew.append(g.skew[j])
                sign.append(g.skew_sign[j])
                base[d] = self.base[d] + g_base
            elif rg == rf and g_base == 0:
                # full-radix chain: rotations add mod r
                src.append(d)
                rot.append((g.rot[j] + self.rot[k]) % rf)
                if self.skew[k] != -1:
                    if not f_plain(self.skew[k]):
                        raise ValueError("cross terms do not compose here")
                    # f's skew source digit must pass through g untouched
                    k2 = self.skew[k]
                    j2 = next(
                        (t for t in range(mo) if g.src[t] == k2
                         and g.rot[t] == 0 and g.skew[t] == -1
                         and g.base[k2] == 0
                         and g.out_digits[t] == self.out_digits[k2]),
                        None,
                    )
                    if j2 is None:
                        raise ValueError("skew source not identity under g")
                    if g.skew[j] == -1:
                        skew.append(j2)
                        sign.append(self.skew_sign[k])
                    elif (g.skew[j] == j2
                          and g.skew_sign[j] + self.skew_sign[k] == 0):
                        # opposite cross terms on the same source cancel
                        # (the f . f^-1 case): a plain rotated digit remains
                        skew.append(-1)
                        sign.append(1)
                    else:
                        raise ValueError("cross terms do not compose here")
                else:
                    skew.append(g.skew[j])
                    sign.append(g.skew_sign[j])
            else:
                raise ValueError("composition not digit-affine representable")
        # self-out digits g never reads are pinned at g's base: fold the
        # constant through self's digit function
        read = set(g.src)
        for k in range(len(self.out_digits)):
            if k in read:
                continue
            if self.skew[k] != -1:
                raise ValueError("cannot pin a skewed digit to a constant")
            d = self.src[k]
            base[d] = self.base[d] + (
                (g.base[k] + self.rot[k]) % self.out_digits[k]
            )
        return AffineMap(self.in_digits, g.out_digits, tuple(src),
                         tuple(base), tuple(rot), tuple(skew), tuple(sign))

    # -- materialization ----------------------------------------------------

    def index_vector(self) -> np.ndarray:
        """Flat input index per flat output index (int64, length n_out) —
        the materialized gather table the kernels make redundant.  Oracle /
        test surface; vectorized numpy."""
        mo = len(self.out_digits)
        flat = np.arange(self.n_out, dtype=np.int64)
        # output digit coordinates
        o = []
        w = self.n_out
        for j in range(mo):
            w //= self.out_digits[j]
            o.append((flat // w) % self.out_digits[j])
        in_w = {}
        w = 1
        for i in reversed(range(len(self.in_digits))):
            in_w[i] = w
            w *= self.in_digits[i]
        out = np.zeros_like(flat)
        mapped = set()
        for j in range(mo):
            d = self.src[j]
            mapped.add(d)
            v = o[j] + self.rot[j]
            if self.skew[j] != -1:
                v = v + self.skew_sign[j] * o[self.skew[j]]
            c = self.base[d] + np.mod(v, self.out_digits[j])
            out += c * in_w[d]
        for i in range(len(self.in_digits)):
            if i not in mapped:
                out += self.base[i] * in_w[i]
        return out


# ---------------------------------------------------------------------------
# recognizers: request -> AffineMap
# ---------------------------------------------------------------------------


def factor_digits(n: int, max_digits: int = 8) -> tuple[int, ...]:
    """Mixed-radix factorization of ``n`` (ascending prime factors, merged
    pairwise until at most ``max_digits`` remain).  Primes give the single
    digit ``(n,)`` — a rotation-only shuffle space, documented weak."""
    if n <= 1:
        return (max(n, 1),)
    digits, m, p = [], n, 2
    while p * p <= m:
        while m % p == 0:
            digits.append(p)
            m //= p
        p += 1
    if m > 1:
        digits.append(m)
    while len(digits) > max_digits:
        digits = sorted(digits)
        digits = [digits[0] * digits[1]] + digits[2:]
    return tuple(sorted(digits, reverse=True))


def bit_reversal_map(shape, axis: int = 0) -> AffineMap:
    """Bit-reversal permutation of ``shape[axis]`` (must be a power of two)
    — the FFT layout transform, as a digit-reversed routing over the axis's
    binary digit split."""
    shape = tuple(int(s) for s in shape)
    n = shape[axis]
    if n < 1 or n & (n - 1):
        raise ValueError(f"bit_reversal axis size {n} is not a power of two")
    amap = AffineMap.identity(shape)
    k = n.bit_length() - 1
    if k == 0:
        return amap
    amap = amap.digit_split(axis, (2,) * k)
    # reverse the k binary digits of the axis in the output routing
    src = list(amap.src)
    src[axis:axis + k] = reversed(src[axis:axis + k])
    return replace(amap, src=tuple(src))


def strided_map(shape, axis: int, stride: int, phase: int = 0) -> AffineMap:
    """The strided gather ``x[..., phase::stride, ...]`` on ``axis``
    (``shape[axis]`` divisible by ``stride``): a digit split into
    (n//stride, stride) with the stride digit pinned at ``phase`` — a
    window in digit space."""
    shape = tuple(int(s) for s in shape)
    n, axis = shape[axis], int(axis)
    if stride < 1 or n % stride:
        raise ValueError(f"stride {stride} does not divide axis size {n}")
    if not 0 <= phase < stride:
        raise ValueError(f"phase {phase} outside [0, {stride})")
    if stride == 1:
        return AffineMap.identity(shape)
    amap = AffineMap.identity(shape).digit_split(axis, (n // stride, stride))
    # drop the stride digit from the outputs; pin it at phase
    keep = [j for j in range(len(amap.out_digits)) if j != axis + 1]
    base = list(amap.base)
    base[amap.src[axis + 1]] = phase
    return AffineMap(
        amap.in_digits,
        tuple(amap.out_digits[j] for j in keep),
        tuple(amap.src[j] for j in keep),
        tuple(base),
        tuple(amap.rot[j] for j in keep),
        (-1,) * len(keep),
        (1,) * len(keep),
    )


def diagonal_map(shape) -> AffineMap:
    """The paper's diagonal reorder on the trailing plane:
    ``out[..., i, j] = in[..., i, (i + j) mod C]`` — one +1 cross term on
    the lane digit (partition-camping-free diagonal walk, DESIGN.md §8)."""
    shape = tuple(int(s) for s in shape)
    if len(shape) < 2:
        raise ValueError("diagonal_map needs a trailing (R, C) plane")
    n = len(shape)
    amap = AffineMap.identity(shape)
    skew = list(amap.skew)
    sign = list(amap.skew_sign)
    skew[n - 1] = n - 2
    sign[n - 1] = 1
    return replace(amap, skew=tuple(skew), skew_sign=tuple(sign))


def shuffle_map(n_rows: int, payload=(), seed: int = 0) -> AffineMap:
    """Seeded bijective row shuffle as an affine map: the row index's
    mixed-radix digits get a seeded permutation plus per-digit rotations
    (Mitchell et al., arXiv:2106.06161 — a bijective index *function*, so
    the kernel needs no gather table in HBM).  ``payload`` axes append as
    identity digits (rows move whole).  Affine shuffles are cache-friendly
    epoch shuffles, not cryptographic ones."""
    payload = tuple(int(s) for s in payload)
    digits = factor_digits(int(n_rows))
    k = len(digits)
    rng = np.random.default_rng(seed)
    perm = tuple(int(p) for p in rng.permutation(k))
    out_digits = tuple(digits[p] for p in perm)
    rot = tuple(int(rng.integers(0, r)) for r in out_digits)
    np_ = len(payload)
    return AffineMap(
        digits + payload,
        out_digits + payload,
        perm + tuple(range(k, k + np_)),
        (0,) * (k + np_),
        rot + (0,) * np_,
        (-1,) * (k + np_),
        (1,) * (k + np_),
    )


def _divisors(m: int) -> tuple[int, ...]:
    """Divisors of ``m`` in ``[2, m]``, ascending.  The peel loop probes
    small radixes first (finest decomposition) but needs composite ones
    too: a rotation on a composite digit (e.g. radix 4, rot 3) carries
    between its prime sub-digits, so only the composite probe matches."""
    small, large = [], []
    d = 2
    while d * d <= m:
        if m % d == 0:
            small.append(d)
            if d != m // d:
                large.append(m // d)
        d += 1
    return tuple(small) + tuple(reversed(large)) + ((m,) if m > 1 else ())


def _probe_digit(vals, r: int) -> tuple[int, int] | None:
    """Recover (stride, rot) when ``vals`` (length r) follows
    ``const + stride * ((o + rot) % r)``; None otherwise."""
    d = np.diff(vals)
    pos = sorted({int(v) for v in d.tolist() if v > 0})
    if len(pos) == 1:
        stride = pos[0]
        wraps = np.flatnonzero(d != stride)
        if len(wraps) == 0:
            return stride, 0  # no wrap inside the probe: rotation-free
        if len(wraps) == 1 and int(d[wraps[0]]) == -(r - 1) * stride:
            return stride, r - 1 - int(wraps[0])  # wrap at o == r-1-rot
        return None
    if not pos and r == 2 and int(d[0]) < 0:
        return -int(d[0]), 1  # radix 2, rotated: the single diff is the wrap
    return None


def recognize_index_vector(idx) -> AffineMap | None:
    """Try to recognize an arbitrary flat permutation vector as a no-skew
    affine digit map (separable per-digit mod-affine).  Returns the map, or
    None — the caller then falls back to the generic gather route (this is
    the 'non-affine requests refused' contract).

    The out-digit structure is *discovered*, not assumed: digits are peeled
    from the minor (fastest-varying) end — a candidate radix ``p`` (every
    divisor of the residual length, smallest first so plain digits peel
    finest) is accepted when every consecutive group of ``p`` entries
    follows one shared ``stride * ((o + rot) % p)`` pattern on top of a
    per-group base, then the per-group bases form the residual vector for
    the next peel."""
    idx = np.asarray(idx, dtype=np.int64)
    n = int(idx.shape[0])
    if n == 0 or sorted(idx.tolist()) != list(range(n)):
        return None
    if n == 1:
        return AffineMap.identity((1,))
    peeled = []  # (radix, stride, rot), minor -> major
    cur = idx
    m = n
    while m > 1:
        found = False
        for p in _divisors(m):
            groups = cur.reshape(m // p, p)
            rec = _probe_digit(groups[0], p)
            if rec is None:
                continue
            stride, rot = rec
            pattern = stride * ((np.arange(p) + rot) % p)
            bases = groups - pattern[None, :]
            if (bases == bases[:, :1]).all():
                peeled.append((p, stride, rot))
                cur = bases[:, 0]
                m //= p
                found = True
                break
        if not found:
            return None
    out_digits = tuple(r for r, _, _ in reversed(peeled))
    k = len(out_digits)
    recovered = [(s, r, rot) for r, s, rot in reversed(peeled)]
    # strides must form a mixed-radix weight set: sort descending and check
    order = sorted(range(k), key=lambda j: -recovered[j][0])
    in_digits = tuple(recovered[j][1] for j in order)
    src = tuple(order.index(j) for j in range(k))
    expect_w = 1
    for pos in reversed(range(k)):
        if recovered[order[pos]][0] != expect_w:
            return None
        expect_w *= in_digits[pos]
    amap = AffineMap(
        in_digits, out_digits,
        tuple(src[j] for j in range(k)),
        (0,) * k,
        tuple(recovered[j][2] for j in range(k)),
        (-1,) * k, (1,) * k,
    )
    if not np.array_equal(amap.index_vector(), idx):
        return None
    return amap


# ---------------------------------------------------------------------------
# coalescing projection + closed-form derivation
# ---------------------------------------------------------------------------


def merge_runs(amap: AffineMap) -> AffineMap:
    """Coalesce the map: drop radix-1 digits and merge adjacent plain
    output digits whose sources are adjacent input digits — the affine form
    of ``layout.coalesce`` (asserted equivalent in the property tests).
    Contiguity run-lengths of the merged map are what the closed-form tile
    derivation reads."""
    m = amap
    changed = True
    while changed:
        changed = False
        skew_into = {k for k in m.skew if k >= 0}
        # drop radix-1 output digits (and their input digit when full-radix)
        for j in range(len(m.out_digits)):
            if (m.out_digits[j] == 1 and j not in skew_into
                    and m.skew[j] == -1
                    and m.in_digits[m.src[j]] == 1):
                m = _drop_digit(m, j)
                changed = True
                break
        if changed:
            continue
        # merge j (outer) with j+1 (inner): inner must be plain full-radix
        for j in range(len(m.out_digits) - 1):
            d0, d1 = m.src[j], m.src[j + 1]
            if (
                d1 == d0 + 1
                and m.rot[j] == 0 and m.rot[j + 1] == 0
                and m.skew[j] == -1 and m.skew[j + 1] == -1
                and j not in skew_into and (j + 1) not in skew_into
                and m.out_digits[j + 1] == m.in_digits[d1]
                and m.base[d1] == 0
            ):
                m = _merge_pair(m, j)
                changed = True
                break
    return m


def _drop_digit(m: AffineMap, j: int) -> AffineMap:
    """Remove radix-1 output digit ``j`` and its radix-1 input digit."""
    d = m.src[j]

    def si(i):
        return i if i < d else i - 1

    keep = [t for t in range(len(m.out_digits)) if t != j]
    return AffineMap(
        m.in_digits[:d] + m.in_digits[d + 1:],
        tuple(m.out_digits[t] for t in keep),
        tuple(si(m.src[t]) for t in keep),
        m.base[:d] + m.base[d + 1:],
        tuple(m.rot[t] for t in keep),
        tuple(
            (m.skew[t] if m.skew[t] < j else m.skew[t] - 1)
            if m.skew[t] != -1 else -1
            for t in keep
        ),
        tuple(m.skew_sign[t] for t in keep),
    )


def _merge_pair(m: AffineMap, j: int) -> AffineMap:
    """Merge output digits (j, j+1) reading adjacent input digits
    (d, d+1): one digit of radix ``r_j * r_{j+1}``, outer base scaled."""
    d = m.src[j]
    rin = m.in_digits[d] * m.in_digits[d + 1]
    rout = m.out_digits[j] * m.out_digits[j + 1]
    in_digits = m.in_digits[:d] + (rin,) + m.in_digits[d + 2:]
    base = list(m.base[:d] + (m.base[d] * m.in_digits[d + 1],)
                + m.base[d + 2:])

    def si(i):
        return i if i <= d else i - 1

    keep = [t for t in range(len(m.out_digits)) if t != j + 1]
    out_digits, src, rot, skew, sign = [], [], [], [], []
    for t in keep:
        out_digits.append(rout if t == j else m.out_digits[t])
        src.append(si(m.src[t]))
        rot.append(m.rot[t])
        s = m.skew[t]
        skew.append(s if s == -1 or s <= j else s - 1)
        sign.append(m.skew_sign[t])
    return AffineMap(in_digits, tuple(out_digits), tuple(src), tuple(base),
                     tuple(rot), tuple(skew), tuple(sign))


@dataclass(frozen=True)
class AffineExec:
    """Closed-form execution plan for one recognized map: the (merged) map,
    the routed mode, the two blocked output digits, and the derived tiles
    (DESIGN.md §14).  ``mode`` reuses the planner's route names; the new
    ``affine`` mode is the generalized reorder kernel."""

    amap: AffineMap  # merged form (what the kernel executes)
    mode: str  # identity | copy | transpose | reorder | affine
    jr: int | None  # blocked output digit, row side
    jc: int | None  # blocked output digit, lane side
    block_r: int
    block_c: int
    block_v: int | None
    exec_shape: tuple[int, ...] | None  # (B, R, C, V) for the swap family
    grid_order: str
    resident_skew: bool  # lane digit adjusted in-kernel (diagonal)


def derive(amap: AffineMap, dtype_name, grid_order: str = "out") -> AffineExec:
    """Derive the bandwidth-optimal tiling in closed form (2306.07795):
    merge contiguity runs, then block the output-fastest run (store side)
    and the run fed by the input-fastest digit (load side); block sizes
    come from the same VMEM/alignment arithmetic the heuristic planners
    use, applied to the run lengths — so for the already-routed permutation
    class the derivation reproduces the heuristic tile *exactly* (the
    SAME-object plan identity in core/plan.py relies on this)."""
    from repro.core import layout  # lazy: layout imports this module

    m = merge_runs(amap)
    outd, ind = m.out_digits, m.in_digits
    mo, ni = len(outd), len(ind)

    if m.is_permutation():
        # the rearrange class: the merged map *is* a (shape, perm) pair —
        # classify and tile exactly like the heuristic planner route
        cshape, cperm = ind, m.src
        if mo <= 1 or cperm == tuple(range(mo)):
            last = amap.in_digits[-1] if amap.in_digits else 1
            tp = plan_copy_tiles(max(m.n_in // max(last, 1), 1), last,
                                 dtype_name)
            return AffineExec(m, "identity", None, None, tp.block_r,
                              tp.block_c, None, None, grid_order, False)
        factors = layout.swap_factors(cshape, cperm)
        if factors is not None:
            b, r, c, v = factors
            if v > 1:
                vp = plan_transpose_vec_tiles(r, c, v, dtype_name)
                return AffineExec(m, "transpose", None, None, vp.block_r,
                                  vp.block_c, vp.block_v, (b, r, c, v),
                                  grid_order, False)
            tp = plan_transpose_tiles(r, c, dtype_name)
            return AffineExec(m, "transpose", None, None, tp.block_r,
                              tp.block_c, None, (b, r, c, v), grid_order,
                              False)
        if cperm[-1] == mo - 1:
            rows_axis, cols_axis = cperm[-2], mo - 1
            tp = plan_copy_tiles(cshape[rows_axis], cshape[cols_axis],
                                 dtype_name)
            return AffineExec(m, "copy", rows_axis, cols_axis, tp.block_r,
                              tp.block_c, None, None, grid_order, False)
        rows_axis, cols_axis = cperm[-1], mo - 1
        tp = plan_transpose_tiles(cshape[rows_axis], cshape[cols_axis],
                                  dtype_name)
        return AffineExec(m, "reorder", rows_axis, cols_axis, tp.block_r,
                          tp.block_c, None, None, grid_order, False)

    # general affine route: pick the two blockable output digits
    skew_into = {k for k in m.skew if k >= 0}

    def blockable(j):
        return m.rot[j] == 0 and m.skew[j] == -1 and j not in skew_into

    if mo == 0:
        raise ValueError("empty output digit space")
    jc = mo - 1
    resident = False
    if not blockable(jc):
        d = m.src[jc]
        full = outd[jc] == ind[d] and m.base[d] == 0
        if full and jc not in skew_into:
            # skewed or rotated lane digit: keep it fully resident and let
            # the kernel apply the modular shift in-register
            resident = True
        else:
            raise ValueError("lane digit not blockable: no affine lowering")
    copy_like = m.src[jc] == ni - 1 or resident

    def row_ok(j):
        # a skew *source* digit may still be row-blocked when the lane digit
        # is resident: the kernel folds its coordinate into per-row shifts
        if blockable(j):
            return True
        return (resident and m.rot[j] == 0 and m.skew[j] == -1
                and j == m.skew[jc])

    jr = None
    if not copy_like:
        jr = next((j for j in range(mo - 1) if m.src[j] == ni - 1
                   and row_ok(j)), None)
    if jr is None and mo >= 2 and row_ok(mo - 2) and mo - 2 != jc:
        jr = mo - 2
    R = outd[jr] if jr is not None else 1
    C = outd[jc]
    if copy_like:
        tp = plan_copy_tiles(max(R, 1), C, dtype_name)
    else:
        tp = plan_transpose_tiles(max(R, 1), C, dtype_name)
    br = min(tp.block_r, R) if jr is not None else 1
    bc = C if resident else min(tp.block_c, C)
    # window bases on blocked digits must ride as whole blocks
    if jr is not None:
        br = align_block(br, m.base[m.src[jr]])
    if not resident:
        bc = align_block(bc, m.base[m.src[jc]])
    return AffineExec(m, "affine", jr, jc, br, bc, None, None, grid_order,
                      resident)
