"""RecurrentGemma-2B [arXiv:2402.19427; hf] — Griffin hybrid: RG-LRU
recurrent blocks + local attention, 1 attention per 2 recurrent (unit
r,r,local; 26 = 8*3 + 2 remainder).  MQA (kv=1), GeGLU, window 2048.
Sub-quadratic: runs long_500k with constant-size state."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    act="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    window=2048,
    tie_embeddings=True,
    unit=("rglru", "rglru", "local"),
    subquadratic=True,
    source="arXiv:2402.19427 (hf: google/recurrentgemma-2b)",
)
