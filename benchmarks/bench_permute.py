"""Paper Table 1: 3D permute, all 6 orders, 128x256x512 fp32 — plus the
split-heads permute family, benchmarked engine-vs-seed.

The split-heads rows compare the plan engine (axis collapsing + batched
2-D transpose routing, core/plan.py) against the seed generic
``permute_nd`` path on the hottest permutation in the codebase:
(B, S, H, D) -> (0, 2, 1, 3).  Off-TPU the comparison runs both paths
through the Pallas interpreter so the kernels (not the XLA oracle) are
measured; on TPU both compile natively.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, smoke, time_fn
from repro.core import layout
from repro.core.plan import plan_rearrange
from repro.kernels import ops
from repro.kernels import reorder_nd as rnd_k

ORDERS = [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]]


def _head_shapes() -> list[tuple]:
    """The transformer head permute: (B, S, H, D) and its inverse layout."""
    if smoke():
        return [
            ("split_heads", (2, 64, 4, 16), (0, 2, 1, 3)),
            ("merge_heads", (2, 4, 64, 16), (0, 2, 1, 3)),
        ]
    return [
        ("split_heads", (8, 512, 16, 64), (0, 2, 1, 3)),
        ("merge_heads", (8, 16, 512, 64), (0, 2, 1, 3)),
    ]


def _table1() -> list[str]:
    shape = (16, 32, 64) if smoke() else (128, 256, 512)
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal(shape), jnp.float32
    )
    nbytes = 2 * x.nbytes
    out = []
    measured = "pallas" if ops.use_pallas() else "xla_oracle"
    for order in ORDERS:
        perm = layout.paper_order_to_perm(order)
        fn = jax.jit(lambda a, p=perm: ops.permute(a, p))
        t = time_fn(fn, x)
        plan = plan_rearrange(x.shape, x.dtype, perm)
        out.append(
            row(
                f"permute3d_{''.join(map(str, order))}",
                t,
                nbytes,
                f"[{plan.mode}]",
                plan_mode=plan.mode,
                kernel=plan.kernel,
                measured=measured,
            )
        )
    return out


def _head_family() -> list[str]:
    out = []
    rng = np.random.default_rng(1)
    force_interp = jax.default_backend() != "tpu"
    prev = os.environ.get("REPRO_PALLAS_INTERPRET")
    if force_interp:
        os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    try:
        for name, shape, perm in _head_shapes():
            x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            nbytes = 2 * x.nbytes
            plan = plan_rearrange(shape, x.dtype, perm)
            t_engine = time_fn(jax.jit(lambda a, p=perm: ops.permute(a, p)), x)
            t_seed = time_fn(
                jax.jit(lambda a, p=perm: rnd_k.permute_nd(a, p)), x
            )
            out.append(
                row(
                    f"{name}_engine",
                    t_engine,
                    nbytes,
                    f"[{plan.mode}, {t_seed/t_engine:.2f}x vs seed]",
                    plan_mode=plan.mode,
                    kernel=plan.kernel,
                    measured="pallas",
                    plan_source=plan.plan_source,
                    tiles=f"{plan.block_r}x{plan.block_c}",
                    improvement_vs_seed=round(t_seed / t_engine, 3),
                )
            )
            # the closed-form plan timed on its own row (DESIGN.md §14):
            # by the bit-identity contract this is the SAME plan object as
            # the engine row when the derivation matched, so the pair
            # tracks analytic-vs-heuristic as a pure noise measurement —
            # tools/check_bench.py holds it to a tolerance-banded 1.0
            t_analytic = time_fn(
                jax.jit(lambda a, p=plan: ops.apply_plan(a, p)), x
            )
            out.append(
                row(
                    f"{name}_analytic",
                    t_analytic,
                    nbytes,
                    f"[source={plan.plan_source}, "
                    f"{t_engine/t_analytic:.2f}x vs engine]",
                    plan_mode=plan.mode,
                    kernel=plan.kernel,
                    measured="pallas",
                    plan_source=plan.plan_source,
                    tiles=f"{plan.block_r}x{plan.block_c}",
                )
            )
            out.append(
                row(
                    f"{name}_seed_generic",
                    t_seed,
                    nbytes,
                    "[seed permute_nd]",
                    plan_mode="seed_generic",
                    kernel="reorder_nd",
                    measured="pallas",
                )
            )
            # the autotuned plan next to the heuristic one (DESIGN.md §11):
            # measured selection on TPU, deterministic cost model elsewhere
            plan_t = plan_rearrange(shape, x.dtype, perm, tuned=True)
            t_tuned = time_fn(jax.jit(lambda a, p=plan_t: ops.apply_plan(a, p)), x)
            out.append(
                row(
                    f"{name}_tuned",
                    t_tuned,
                    nbytes,
                    f"[tiles {plan_t.block_r}x{plan_t.block_c} vs "
                    f"{plan.block_r}x{plan.block_c} heuristic, "
                    f"{t_engine/t_tuned:.2f}x]",
                    plan_mode=plan_t.mode,
                    kernel=plan_t.kernel,
                    measured="pallas",
                    plan_source="tuned",
                    tiles=f"{plan_t.block_r}x{plan_t.block_c}",
                    tiles_heuristic=f"{plan.block_r}x{plan.block_c}",
                    improvement_vs_heuristic=round(t_engine / t_tuned, 3),
                )
            )
    finally:
        if force_interp:
            if prev is None:
                os.environ.pop("REPRO_PALLAS_INTERPRET", None)
            else:
                os.environ["REPRO_PALLAS_INTERPRET"] = prev
    return out


def _affine_ops() -> list[str]:
    """The ops the analytic planner unlocks (DESIGN.md §14): bit-reversal,
    diagonal reorder, and the table-free seeded shuffle, each ONE
    pallas_call planned by `plan_affine` (plan_source=analytic).  The
    shuffle's gather-table oracle rides along as the baseline the affine
    route makes redundant."""
    from repro.core import affine
    from repro.core.plan import plan_affine
    from repro.kernels import ref

    out = []
    rng = np.random.default_rng(2)
    if smoke():
        n_rows, payload, plane = 256, 64, (64, 128)
    else:
        # moderate sizes: the rotated-digit routes grid one step per batch
        # digit combination, and off-TPU they time under the interpreter
        n_rows, payload, plane = 4096, 256, (1024, 1024)
    force_interp = jax.default_backend() != "tpu"
    prev = os.environ.get("REPRO_PALLAS_INTERPRET")
    if force_interp:
        os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    try:
        cases = (
            ("bit_reversal", affine.bit_reversal_map((n_rows, payload)),
             lambda a: ops.bit_reversal(a, axis=0)),
            ("diagonal_reorder", affine.diagonal_map(plane),
             lambda a: ops.diagonal_reorder(a)),
            ("shuffle", affine.shuffle_map(n_rows, payload=(payload,), seed=0),
             lambda a: ops.shuffle(a, seed=0)),
        )
        for name, amap, fn in cases:
            plan = plan_affine(amap, jnp.float32)
            x = jnp.asarray(
                rng.standard_normal(amap.in_digits), jnp.float32
            ).reshape(
                plane if name == "diagonal_reorder" else (n_rows, payload)
            )
            nbytes = 2 * x.nbytes
            t = time_fn(jax.jit(fn), x)
            out.append(
                row(
                    f"{name}_affine",
                    t,
                    nbytes,
                    f"[{plan.mode}, tiles {plan.block_r}x{plan.block_c}]",
                    plan_mode=plan.mode,
                    kernel=plan.kernel,
                    measured="pallas",
                    plan_source=plan.plan_source,
                    tiles=f"{plan.block_r}x{plan.block_c}",
                )
            )
        xs = jnp.asarray(
            rng.standard_normal((n_rows, payload)), jnp.float32
        )
        t_table = time_fn(jax.jit(lambda a: ref.shuffle(a, seed=0)), xs)
        out.append(
            row(
                "shuffle_table_oracle",
                t_table,
                2 * xs.nbytes,
                "[materialized gather table]",
                plan_mode="oracle",
                kernel="jnp_take",
                measured="xla_oracle",
            )
        )
    finally:
        if force_interp:
            if prev is None:
                os.environ.pop("REPRO_PALLAS_INTERPRET", None)
            else:
                os.environ["REPRO_PALLAS_INTERPRET"] = prev
    return out


def run() -> list[str]:
    return _table1() + _head_family() + _affine_ops()
