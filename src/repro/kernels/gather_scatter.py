"""Index-set read/write kernels (paper §III-A "specified set of indices").

The paper's basic access kernels support gathering/scattering rows by an
index table; in CUDA the table lives in constant memory.  On TPU the table
is **scalar-prefetched** (`pltpu.PrefetchScalarGridSpec`): it lands in SMEM
before the grid runs, and the kernel reads it to choose which rows each
grid step DMAs.  This is the exact functional analogue of constant memory:
small, uniformly read metadata off the datapath.

Two generations of kernels live here (DESIGN.md §4):

* **row-wise** (`gather_rows` / `scatter_rows`) — the seed kernels: one
  grid step per row, the row choice riding in the BlockSpec ``index_map``.
  Kept as the benchmark baseline and the fallback for exotic shapes.
* **blocked** (`gather_rows_blocked` / `gather_combine_blocked`) — the
  IndexPlan-engine kernels (`core/index_plan.py`): the index table is
  reshaped to ``(nB, br)`` row blocks so each grid step moves ``br`` rows
  off an HBM-resident source via explicit async copies, with

  - **run detection**: a block whose indices form a contiguous run
    (``idx[base + r] == idx[base] + r``) collapses to ONE strided block
    copy — the index-table analogue of the rearrangement planner's axis
    collapsing, resolved at run time because the table is data;
  - **in-kernel sentinel masking**: a negative index zero-fills its row
    (``pl.when``), so callers never concatenate sentinel rows onto the
    source array;
  - a **fused gather+weighted-combine** form: ``out[t] = sum_k
    gates[t, k] * src[back[t, k]]`` in one kernel — the whole MoE combine
    (gather -> reshape -> multiply -> sum) as a single `pallas_call`.

These kernels are the framework's MoE dispatch/combine primitives: token
permutation by expert id is precisely an index-set gather (DESIGN.md §4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import cdiv, force_interpret, plan_copy_tiles

# ---------------------------------------------------------------------------
# row-wise kernels (seed generation; benchmark baseline)
# ---------------------------------------------------------------------------


def _copy_row_kernel(idx_ref, x_ref, o_ref):
    del idx_ref  # consumed by the index maps
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def gather_rows(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """out[i, :] = x[idx[i], :].  idx: int32 (num_out,).

    Row-wise seed kernel: one grid step (one DMA) per output row, the
    source row riding in the input BlockSpec ``index_map``.  The blocked
    generation (:func:`gather_rows_blocked`) moves ``br`` rows per step.
    """
    if x.ndim != 2 or idx.ndim != 1:
        raise ValueError(f"gather_rows wants 2-D x and 1-D idx, got {x.shape}, {idx.shape}")
    n_out = idx.shape[0]
    C = x.shape[1]
    bc = min(block_c or plan_copy_tiles(1, C, x.dtype).block_c, C)
    nC = cdiv(C, bc)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out, nC),
        in_specs=[pl.BlockSpec((1, bc), lambda i, j, idx_ref: (idx_ref[i], j))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, idx_ref: (i, j)),
    )
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, C), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def scatter_rows(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """out[idx[i], :] = x[i, :].  ``idx`` must be a permutation of
    range(x.shape[0]) — every output row is written exactly once.

    Row-wise seed kernel; the IndexPlan engine executes general (capacity)
    scatters as a masked blocked gather through the inverted table
    (`kernels.ops.scatter_rows`).
    """
    if x.ndim != 2 or idx.ndim != 1 or idx.shape[0] != x.shape[0]:
        raise ValueError(f"scatter_rows wants idx over rows, got {x.shape}, {idx.shape}")
    n = x.shape[0]
    C = x.shape[1]
    bc = min(block_c or plan_copy_tiles(1, C, x.dtype).block_c, C)
    nC = cdiv(C, bc)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, nC),
        in_specs=[pl.BlockSpec((1, bc), lambda i, j, idx_ref: (i, j))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, idx_ref: (idx_ref[i], j)),
    )
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, C), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x)


# ---------------------------------------------------------------------------
# blocked kernels (IndexPlan engine generation)
# ---------------------------------------------------------------------------


def _pad_table(idx: jax.Array, rows: int) -> jax.Array:
    """Pad the int32 index table to ``rows`` entries with the sentinel -1
    (no concatenate: a full-sized fill + static-slice update)."""
    idx = idx.astype(jnp.int32)
    n = idx.shape[0]
    if n == rows:
        return idx
    return jnp.full((rows,), -1, jnp.int32).at[:n].set(idx)


def _row_dma(src_hbm, s, rows_vmem, r, sem):
    """Copy one source row ``s`` (HBM) into scratch row ``r`` (VMEM)."""
    cp = pltpu.make_async_copy(
        src_hbm.at[pl.ds(s, 1), :], rows_vmem.at[pl.ds(r, 1), :], sem
    )
    cp.start()
    cp.wait()


def _gather_block_kernel(use_run: bool, idx_ref, x_hbm, o_ref, rows, sem):
    """One grid step = one (br, C) output block.

    Run detection first: when the block's br indices are a contiguous run,
    ONE strided block copy fetches all rows; otherwise rows are copied
    one DMA each, with negative (sentinel) indices zero-filled in VMEM.
    ``use_run`` is static — False when br > n_src, where a br-row run
    cannot exist (and the block-copy slice would be statically invalid).
    """
    i = pl.program_id(0)
    br, C = o_ref.shape
    base = i * br
    start = idx_ref[base]

    def _row_path(_):
        def body(r, carry):
            s = idx_ref[base + r]

            @pl.when(s >= 0)
            def _():
                _row_dma(x_hbm, s, rows, r, sem)

            @pl.when(s < 0)
            def _():
                rows[pl.ds(r, 1), :] = jnp.zeros((1, C), o_ref.dtype)

            return carry

        jax.lax.fori_loop(0, br, body, 0)
        return 0

    if use_run:

        def _consecutive(r, ok):
            return jnp.logical_and(ok, idx_ref[base + r] == start + r)

        is_run = jax.lax.fori_loop(1, br, _consecutive, start >= 0)

        def _run_path(_):
            cp = pltpu.make_async_copy(
                x_hbm.at[pl.ds(start, br), :], rows.at[:, :], sem
            )
            cp.start()
            cp.wait()
            return 0

        jax.lax.cond(is_run, _run_path, _row_path, 0)
    else:
        _row_path(0)
    o_ref[...] = rows[...]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def gather_rows_blocked(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_r: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked masked gather: ``out[i, :] = x[idx[i], :]``, ``idx[i] < 0``
    -> zero row.

    The index table is reshaped to ``(nB, block_r)`` row blocks; each grid
    step moves ``block_r`` full-width rows off the HBM-resident source.
    Contiguous index runs collapse to one strided block copy (run
    detection), and sentinel rows are zero-filled in-kernel — no caller-
    side sentinel-row concatenates.  Planned by
    :func:`repro.core.index_plan.plan_index_op`.

    This one kernel carries three plan semantics: masked ``gather``,
    ``scatter`` (via the inverted table), and the serving engine's
    ``ragged_rows`` unpack (DESIGN.md §12), where per-sequence packed
    rows are contiguous runs — the run-detected strided-copy fast path —
    and the ``-1`` tail sentinels zero-fill each KV ring beyond its
    prompt length.
    """
    if x.ndim != 2 or idx.ndim != 1:
        raise ValueError(
            f"gather_rows_blocked wants 2-D x and 1-D idx, got {x.shape}, {idx.shape}"
        )
    n_out = idx.shape[0]
    n_src, C = x.shape
    if n_out == 0 or C == 0 or n_src == 0:
        return jnp.zeros((n_out, C), x.dtype)
    br = max(1, min(block_r, n_out))
    nB = cdiv(n_out, br)
    idxp = _pad_table(idx, nB * br)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nB,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((br, C), lambda i, idx_ref: (i, 0)),
        scratch_shapes=[pltpu.VMEM((br, C), x.dtype), pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        functools.partial(_gather_block_kernel, br <= n_src),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, C), x.dtype),
        interpret=interpret,
    )(idxp, x)


def _gather_combine_kernel(back_ref, src_hbm, gates_ref, o_ref, rows, sem):
    """One grid step = one (bt, C) combined-output block.

    Gathers the block's ``bt * k`` source rows into VMEM (sentinels
    zero-filled), then performs the weighted combine entirely on-chip:
    ``out[t] = sum_k gates[t, k] * rows[t, k]`` — the gathered (T*k, C)
    intermediate never exists in HBM.
    """
    i = pl.program_id(0)
    bt, C = o_ref.shape
    k = gates_ref.shape[1]
    base = i * bt * k

    def body(j, carry):
        s = back_ref[base + j]

        @pl.when(s >= 0)
        def _():
            _row_dma(src_hbm, s, rows, j, sem)

        @pl.when(s < 0)
        def _():
            rows[pl.ds(j, 1), :] = jnp.zeros((1, C), o_ref.dtype)

        return carry

    jax.lax.fori_loop(0, bt * k, body, 0)
    v = rows[...].reshape(bt, k, C)
    g = gates_ref[...].astype(o_ref.dtype)
    o_ref[...] = (v * g[..., None]).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def gather_combine_blocked(
    src: jax.Array,
    back: jax.Array,
    gates: jax.Array,
    *,
    block_t: int = 32,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather + weighted combine (the MoE combine primitive):

        out[t, :] = sum_k gates[t, k] * src[back[t, k], :]

    with ``back[t, k] < 0`` contributing zero.  ``src``: (n_src, C);
    ``back``: int (T, k); ``gates``: (T, k) float.  ONE `pallas_call`
    replaces the seed's gather -> reshape -> multiply -> sum chain, and
    the (T*k, C) gathered intermediate never round-trips HBM.  The
    per-``k`` accumulation order and dtype match the unfused chain
    (products and sum in ``src.dtype``), so results are bit-identical to
    the seed path.  Planned by :func:`repro.core.index_plan.plan_index_op`
    with ``semantics="gather_combine"``.
    """
    if src.ndim != 2 or back.ndim != 2 or gates.shape != back.shape:
        raise ValueError(
            f"gather_combine_blocked wants 2-D src and matching (T, k) "
            f"back/gates, got {src.shape}, {back.shape}, {gates.shape}"
        )
    T, k = back.shape
    n_src, C = src.shape
    if T == 0 or C == 0 or k == 0 or n_src == 0:
        return jnp.zeros((T, C), src.dtype)
    bt = max(1, min(block_t, T))
    nT = cdiv(T, bt)
    backp = _pad_table(back.reshape(-1), nT * bt * k)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nT,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bt, k), lambda i, back_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, C), lambda i, back_ref: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bt * k, C), src.dtype), pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        _gather_combine_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, C), src.dtype),
        interpret=interpret,
    )(backp, src, gates)
