"""Paper Fig. 1: read/write kernel bandwidth over data sizes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import memcpy_gbps, row, smoke, time_fn
from repro.kernels import ops


def _size_tag(nbytes: int) -> str:
    """Human size label for a row name (KB below one MiB — smoke shapes)."""
    if nbytes >= 1024 * 1024:
        return f"{nbytes // (1024 * 1024)}MB"
    return f"{nbytes // 1024}KB"


def run() -> list[str]:
    out = [f"# memcpy baseline: {memcpy_gbps():.2f} GB/s"]
    copy = jax.jit(ops.copy)
    sizes = (1, 2) if smoke() else (4, 16, 64, 256)
    cols = 128 if smoke() else 1024
    for mb in sizes:
        n = mb * 1024 * 1024 // 4
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
        x = x.reshape(-1, cols)
        t = time_fn(copy, x)
        out.append(row(f"copy_{mb}MB", t, 2 * x.nbytes))
    # ranged read
    rows_n = 2048 if smoke() else 65536
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((rows_n, cols)), jnp.float32
    )
    half = rows_n // 2
    half_bytes = half * cols * x.dtype.itemsize
    t = time_fn(jax.jit(lambda a: ops.copy_range(a, jnp.int32(123), half)), x)
    out.append(row(f"copy_range_{_size_tag(half_bytes)}", t, 2 * half_bytes))
    # index-set gather (random permutation rows); traffic counts the data
    # rows both ways plus the int32 index-table stream
    idx = jnp.asarray(np.random.default_rng(1).permutation(rows_n), jnp.int32)
    t = time_fn(jax.jit(ops.gather_rows), x, idx)
    out.append(
        row(f"gather_rows_{_size_tag(2 * half_bytes)}", t, 2 * x.nbytes + idx.nbytes)
    )
    return out
