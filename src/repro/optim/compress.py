"""Quantized ring collectives for gradient reduction (beyond-paper).

A GSPMD all-reduce moves full-precision bytes.  This module implements
the data-parallel gradient reduction explicitly — shard_map + a ring of
``collective_permute`` hops — quantizing every hop to int8 with a per-
chunk fp32 scale: ~4x fewer bytes on the wire than a bf16/fp32 ring,
with error feedback available at the optimizer level.

  reduce-scatter:  n-1 hops, each hop sends 1/n of the tensor (int8)
  all-gather:      n-1 hops of the reduced shard (int8)

Integration: the trainer's DP reduction can route through
``compressed_allreduce_mean`` under shard_map when
``TrainConfig.compress_grads`` is set; the dry-run's collective-bytes
accounting then charges int8 operand bytes (see EXPERIMENTS §Perf).
This module is numerically validated on a forced multi-device host mesh
in tests/test_compress.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import axis_size_compat, shard_map_compat


def _quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ring_allreduce_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-all-reduce of ``x`` over ``axis_name`` with int8 ring hops.
    Call inside shard_map.  x: flat (L,) with L % n == 0."""
    n = axis_size_compat(axis_name)
    me = jax.lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    chunks = x.reshape(n, -1).astype(jnp.float32)

    # --- reduce-scatter: after n-1 hops, device d owns sum of chunk (d+1)%n.
    # step s: d sends its partial of chunk (d-s), receives the partial of
    # chunk (d-1-s) and adds its own contribution to it.
    acc = jnp.take(chunks, me, axis=0)
    for s in range(n - 1):
        q, scale = _quant(acc)
        q = jax.lax.ppermute(q, axis_name, fwd)
        scale = jax.lax.ppermute(scale, axis_name, fwd)
        idx = (me - 1 - s) % n
        acc = _dequant(q, scale) + jnp.take(chunks, idx, axis=0)

    own = (me + 1) % n  # chunk id this device now owns (fully reduced)
    acc = acc / n

    # --- all-gather the reduced shards (int8 hops)
    out = jnp.zeros_like(chunks)
    q, scale = _quant(acc)
    cur_q, cur_scale, cur_idx = q, scale, own
    out = out.at[cur_idx].set(_dequant(cur_q, cur_scale))
    for s in range(n - 1):
        cur_q = jax.lax.ppermute(cur_q, axis_name, fwd)
        cur_scale = jax.lax.ppermute(cur_scale, axis_name, fwd)
        cur_idx = (cur_idx + 1) % n  # my predecessor owned (own - 1)
        idx = (own - 1 - s) % n
        out = out.at[idx].set(_dequant(cur_q, cur_scale))
    return out.reshape(x.shape)


def compressed_allreduce_mean(tree, mesh, *, axis: str = "data"):
    """Mean-reduce a pytree of per-device gradients over the data axis via
    the int8 ring.  Leaves are flattened/padded to a ring-divisible size."""
    n = mesh.shape[axis]

    def one(leaf):
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % n
        flat = jnp.pad(flat, (0, pad))

        fn = shard_map_compat(
            functools.partial(ring_allreduce_int8, axis_name=axis),
            mesh,
            P(),
            P(),
        )
        red = fn(flat)
        return red[: leaf.size].reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, tree)


def wire_bytes(n_params: int, n_devices: int, dtype_bytes: int = 4) -> dict:
    """Napkin accounting: ring AR bytes per device, fp32 vs int8 hops."""
    full = 2 * (n_devices - 1) / n_devices * n_params * dtype_bytes
    quant = 2 * (n_devices - 1) / n_devices * n_params * 1  # int8 payload
    return {"fp32_ring": full, "int8_ring": quant, "ratio": full / quant}
