"""Mixture-of-Experts layer: top-k router + two dispatch strategies.

Dispatch IS the paper's index-set rearrangement (§III-A / DESIGN.md §4):

* ``sort`` mode — tokens are permuted into expert-contiguous order through
  the IndexPlan engine (`core/index_plan.py`): ONE blocked masked gather
  (scalar-prefetched index table = constant-memory analogue, sentinel
  slots zero-filled in-kernel), experts run as a blocked einsum, and ONE
  fused gather+weighted-combine kernel restores token order.  This is the
  TPU-kernel path (single device / serving).
* ``dense`` mode — capacity-bucketed one-hot dispatch/combine einsums
  (the GSPMD-canonical formulation): expert axis sharded on 'model' turns
  the dispatch einsum into an all-to-all.  This is the distributed path
  and the one the dry-run compiles.

Auxiliary load-balancing loss (Switch-style) is returned alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common, mlp

Array = jax.Array


def moe_init(key, cfg) -> dict:
    mc = cfg.moe
    d = cfg.d_model
    f = mc.d_expert
    dt = cfg.np_dtype
    keys = jax.random.split(key, 6)
    p = {
        "norm": common.norm_init(cfg.norm, d),
        "w_router": common.truncated_normal_init(keys[0], (d, mc.n_experts), 1.0, jnp.float32),
        "w_up": common.truncated_normal_init(keys[1], (mc.n_experts, d, f), 1.0, dt),
        "w_gate": common.truncated_normal_init(keys[2], (mc.n_experts, d, f), 1.0, dt),
        "w_down": common.truncated_normal_init(keys[3], (mc.n_experts, f, d), 1.0, dt),
    }
    if mc.n_shared:
        shared_cfg_ff = mc.d_expert * mc.n_shared
        p["shared"] = mlp.mlp_init(keys[4], cfg, d_ff=shared_cfg_ff)
    return p


def _route(p: dict, mc, h2: Array) -> tuple[Array, Array, Array, Array]:
    """h2: (T, D) -> (gates (T,k), idx (T,k), me (E,), ce (E,)).

    ``me``/``ce`` are the per-expert mean router probability and top-1
    assignment fraction over THESE tokens — kept separate from the aux-loss
    reduction so the expert-parallel path can ``pmean`` them across token
    shards before forming the (nonlinear) Switch loss.
    """
    logits = (h2.astype(jnp.float32) @ p["w_router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, mc.top_k)
    if mc.normalize_gates:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    e = mc.n_experts
    me = probs.mean(axis=0)  # (E,)
    onehot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    ce = onehot.mean(axis=0)
    return gates, idx, me, ce


def _router(p: dict, mc, h2: Array) -> tuple[Array, Array, Array]:
    """h2: (T, D) -> (gates (T,k), idx (T,k), aux_loss)."""
    gates, idx, me, ce = _route(p, mc, h2)
    # Switch aux loss: E * sum_e f_e * P_e
    aux = mc.n_experts * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(p: dict, cfg, xe: Array) -> Array:
    """xe: (E, C, D) -> (E, C, D), blocked per-expert einsums."""
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    hidden = jax.nn.silu(gate) * up if cfg.act == "swiglu" else jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", hidden, p["w_down"])


def _slot_assignment(idx: Array, t: int, e: int, cap: int, k: int):
    """Capacity-bucketed rank of every (token, k) assignment.

    Returns ``(keep, slot, token_of)``: ``slot = expert*cap + rank`` in
    ``[0, E*C)``, ``keep`` marks assignments under capacity, ``token_of``
    maps flat assignment index to its token row.  Shared by BOTH dispatch
    engines (rowwise baseline and the §4 plan path) so the bucketing
    semantics cannot diverge between them.
    """
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)           # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = (pos * flat).sum(-1).reshape(t, k)                   # rank in expert
    keep = pos < cap
    slot = idx * cap + pos                                     # (T, k) in [0, E*C)
    token_of = jnp.arange(t * k, dtype=jnp.int32) // k
    return keep, slot, token_of


def _dispatch_tables(idx: Array, t: int, e: int, cap: int, k: int):
    """Sentinel-carrying dispatch tables for the §4 plan path.

    Returns ``(src, back, keep)``: ``src`` (E*cap,) maps each expert slot
    to its source token row (-1 sentinel = empty slot) and ``back`` (T, k)
    maps each assignment to its slot (-1 = dropped) — the in-kernel
    sentinel semantics that make dispatch ONE blocked masked gather and
    combine ONE fused kernel.
    """
    keep, slot, token_of = _slot_assignment(idx, t, e, cap, k)
    slot_or_dump = jnp.where(keep, slot, e * cap).reshape(-1)
    src = jnp.full((e * cap,), -1, jnp.int32).at[slot_or_dump].set(
        token_of, mode="drop"
    )
    back = jnp.where(keep, slot, -1).astype(jnp.int32)         # (T, k)
    return src, back, keep


def moe_dense(p: dict, cfg, x: Array, *, capacity: int | None = None) -> tuple[Array, Array]:
    """Capacity-bucketed dispatch, GShard-style *grouped by sequence*:
    capacity C = cf*S*k/E per batch row, so the dispatch one-hot is
    (B, S, E, C) and dispatch FLOPs stay ~2.5*S^2*D per row (~6% of the
    expert FFN) instead of scaling with GLOBAL tokens — a global capacity
    makes dispatch O(T^2) (the 7500s collective term the dry-run caught,
    EXPERIMENTS §Perf).  Expert axis shards on 'model' -> all-to-all."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import BATCH, constrain

    mc = cfg.moe
    b0, s0, d = x.shape
    h = common.apply_norm(cfg.norm, p["norm"], x)
    if getattr(cfg, "sp", False):
        h = constrain(h, P(BATCH, None, None))  # SP: gather before dispatch
    gates, idx, aux = _router(p, mc, h.reshape(-1, d))
    e, k = mc.n_experts, mc.top_k
    # fixed-size token groups (true GShard): capacity must not grow with
    # S, or the dispatch one-hots/einsums go quadratic at 32k+ prefill
    g_size = s0
    if s0 > 4096:
        for cand in (4096, 2048, 1024):
            if s0 % cand == 0:
                g_size = cand
                break
    b = b0 * (s0 // g_size)
    s = g_size
    h = h.reshape(b, s, d)
    gates = gates.reshape(b, s, k)
    idx = idx.reshape(b, s, k)

    cap = capacity or default_capacity(cfg, s)
    cap = min(cap, s * k)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)            # (B, S, k, E)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                        # rank per (row, expert)
    pos = (pos.reshape(b, s, k, e) * onehot).sum(-1)             # (B, S, k)
    keep = pos < cap
    slot = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=h.dtype)[..., :-1]
    oh = onehot.astype(h.dtype)
    disp = jnp.einsum("bske,bskc->bsec", oh, slot)               # (B, S, E, C)
    ge = oh * (gates * keep.astype(gates.dtype)).astype(h.dtype)[..., None]
    comb = jnp.einsum("bske,bskc->bsec", ge, slot)

    xe = jnp.einsum("bsec,bsd->ebcd", disp, h)                   # (E, B, C, D)
    espec = "model" if mc.shard == "expert" else None
    xe = constrain(xe, P(espec, BATCH, None, None))
    up = jnp.einsum("ebcd,edf->ebcf", xe, p["w_up"])
    gate = jnp.einsum("ebcd,edf->ebcf", xe, p["w_gate"])
    if mc.shard == "ffn":
        up = constrain(up, P(None, BATCH, None, "model"))
        gate = constrain(gate, P(None, BATCH, None, "model"))
    hidden = jax.nn.silu(gate) * up if cfg.act == "swiglu" else jax.nn.gelu(up)
    ye = jnp.einsum("ebcf,efd->ebcd", hidden, p["w_down"])
    ye = constrain(ye, P(espec, BATCH, None, None))
    y = jnp.einsum("bsec,ebcd->bsd", comb, ye.astype(comb.dtype)).astype(x.dtype)
    if "shared" in p:
        y = y + mlp.ffn_only(p["shared"], cfg, h.reshape(-1, d)).reshape(b, s, d)
    return x + y.reshape(b0, s0, d), aux


def moe_sort(
    p: dict, cfg, x: Array, *, capacity: int | None = None, engine: str = "plan"
) -> tuple[Array, Array]:
    """Capacity-blocked gather dispatch through the library's index-set
    kernels (paper §III-A): tokens are gathered into expert-contiguous
    (E, C, D) blocks with a scalar-prefetched source table, experts run as
    blocked einsums, and the combine restores token order.

    ``engine="plan"`` (default) routes through the IndexPlan engine
    (`core/index_plan.py`): dispatch is ONE blocked masked gather (dropped
    slots are in-kernel sentinel zeros — no sentinel-row concatenates) and
    the combine is ONE fused gather+weighted-combine kernel, so the whole
    dispatch+combine is exactly 2 `pallas_call`s.  ``engine="rowwise"``
    keeps the seed path — per-row gathers around two full-array sentinel
    concatenates and an unfused multiply/sum combine — as the benchmark
    baseline (`benchmarks/bench_moe_dispatch.py`).
    """
    if engine not in ("plan", "rowwise"):
        raise ValueError(f"unknown moe_sort engine {engine!r}")
    mc = cfg.moe
    b, s, d = x.shape
    h = common.apply_norm(cfg.norm, p["norm"], x)
    h2 = h.reshape(-1, d)
    t = h2.shape[0]
    gates, idx, aux = _router(p, mc, h2)

    e, k = mc.n_experts, mc.top_k
    cap = capacity or default_capacity(cfg, t)

    if engine == "rowwise":
        keep, slot, token_of = _slot_assignment(idx, t, e, cap, k)
        slot_or_dump = jnp.where(keep, slot, e * cap).reshape(-1)  # dump at end
        # source table: slot -> source token row (sentinel row t = zeros)
        src = jnp.full((e * cap + 1,), t, jnp.int32).at[slot_or_dump].set(token_of)
        h2p = jnp.concatenate([h2, jnp.zeros((1, d), h2.dtype)], axis=0)
        xs = ops.gather_rows(h2p, src[: e * cap], engine="rowwise")
        ye = _expert_ffn(p, cfg, xs.reshape(e, cap, d)).reshape(e * cap, d)
        # gather back: token slot -> expert output row (dump -> zeros row)
        yep = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
        back = jnp.where(keep.reshape(-1), slot.reshape(-1), e * cap).astype(jnp.int32)
        yk = ops.gather_rows(yep, back, engine="rowwise").reshape(t, k, d)
        y = (yk * gates[..., None].astype(yk.dtype)).sum(axis=1).astype(x.dtype)
    else:
        # dispatch: slot -> token table with -1 sentinels for empty slots
        # (dropped assignments target the out-of-range slot e*cap and are
        # dropped by the scatter); the masked blocked gather zero-fills
        # sentinel rows in-kernel -> ONE pallas_call, no h2 concatenate.
        src, back, _ = _dispatch_tables(idx, t, e, cap, k)
        xs = ops.gather_rows(h2, src, masked=True)             # (E*C, D)
        ye = _expert_ffn(p, cfg, xs.reshape(e, cap, d)).reshape(e * cap, d)
        # combine: out[t] = sum_k gates[t,k] * ye[back[t,k]] fused into ONE
        # kernel (dropped assignments carry the -1 sentinel -> zero term)
        y = ops.gather_combine(ye, back, gates).astype(x.dtype)
    if "shared" in p:
        y = y + mlp.ffn_only(p["shared"], cfg, h2)
    return x + y.reshape(b, s, d), aux


def moe_sort_ep(
    p: dict,
    cfg,
    x: Array,
    *,
    mesh,
    axis: str = "model",
    capacity: int | None = None,
) -> tuple[Array, Array]:
    """Expert-parallel sort dispatch: the §4 blocked kernels sandwich a
    capacity-bucketed ``all_to_all`` pair (DESIGN.md §10).

    Tokens shard over mesh ``axis`` (``T`` divisible by its size ``P``) and
    so do experts (``E = P * E_local``).  Per shard: route the local tokens,
    dispatch them into global-expert-major (E, C, D) slot blocks with ONE
    blocked masked gather (`core/index_plan.py` — identical kernel to
    single-device ``moe_sort``), exchange slot blocks with ONE tiled
    ``all_to_all`` so every shard receives exactly the rows its local
    experts own, run the local expert FFNs, ``all_to_all`` back, and
    restore token order with ONE fused gather+combine kernel.  The gathered
    intermediate never touches HBM (fused kernels) and only the
    ``(P-1)/P`` remote fraction of the fixed-size slot blocks touches the
    wire (capacity bucketing is what keeps the exchange fixed-size).

    ``capacity`` is per (source shard, expert); ``capacity >= T/P`` is
    dropless, making the result bit-identical to dropless single-device
    ``moe_sort`` (the aux loss is ``pmean``-reduced, equal to fp rounding).
    """
    from jax.sharding import PartitionSpec as P

    from repro.core import dist_plan
    from repro.launch.mesh import shard_map_compat
    from repro.sharding.partition import ep_param_specs

    mc = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = mc.n_experts, mc.top_k
    p_sz = int(mesh.shape[axis])
    tl = t // p_sz
    cap = capacity or default_capacity(cfg, tl)
    plan = dist_plan.plan_dist_moe(
        dist_plan.mesh_key(mesh), axis, t, d, e, cap, k, x.dtype
    )
    if plan.strategy == "local":
        return moe_sort(p, cfg, x, capacity=cap)
    _, el, _, _ = plan.detail

    pspecs = ep_param_specs(p, axis)  # experts shard over the EP axis

    def f(pl_, xl):
        h2 = common.apply_norm(cfg.norm, pl_["norm"], xl)
        gates, idx, me, ce = _route(pl_, mc, h2)
        # global Switch aux: token shards are equal-sized, so the global
        # means are the pmean of the per-shard means
        me = jax.lax.pmean(me, axis)
        ce = jax.lax.pmean(ce, axis)
        aux = e * jnp.sum(me * ce)
        # local dispatch into global-expert-major slots: slot blocks for
        # destination shard q occupy rows [q*el*cap, (q+1)*el*cap)
        src, back, _ = _dispatch_tables(idx, tl, e, cap, k)
        xs = ops.gather_rows(h2, src, masked=True)              # (E*C, D)
        # wire: shard q receives every source's block q — afterwards rows
        # group as (source shard, local expert, capacity)
        xs = jax.lax.all_to_all(xs, axis, split_axis=0, concat_axis=0, tiled=True)
        # (P, el, cap, D) -> (el, P, cap, D): expert-major for the blocked
        # FFN einsums — a local §3 plan (one batched-transpose kernel)
        xe = ops.permute(xs.reshape(p_sz, el, cap, d), (1, 0, 2, 3))
        ye = _expert_ffn(pl_, cfg, xe.reshape(el, p_sz * cap, d))
        ye = ops.permute(ye.reshape(el, p_sz, cap, d), (1, 0, 2, 3))
        # wire back: every source shard gets its slots home, global-expert
        # order restored
        ye = jax.lax.all_to_all(
            ye.reshape(e * cap, d), axis, split_axis=0, concat_axis=0, tiled=True
        )
        y = ops.gather_combine(ye, back, gates).astype(xl.dtype)
        if "shared" in pl_:
            y = y + mlp.ffn_only(pl_["shared"], cfg, h2)
        return xl + y, aux

    y, aux = shard_map_compat(
        f, mesh, in_specs=(pspecs, P(axis, None)), out_specs=(P(axis, None), P())
    )(p, x.reshape(t, d))
    return y.reshape(b, s, d), aux


def moe_apply(p: dict, cfg, x: Array, *, capacity: int | None = None) -> tuple[Array, Array]:
    """Route to the configured dispatch strategy (``sort`` or ``dense``)."""
    if cfg.moe.dispatch == "sort":
        return moe_sort(p, cfg, x, capacity=capacity)
    return moe_dense(p, cfg, x, capacity=capacity)


def default_capacity(cfg, tokens: int) -> int:
    """Per-expert buffer size for ``tokens`` routed tokens: the GShard
    formula ``max(1, int(capacity_factor * tokens * top_k / n_experts))``.
    The single definition every caller shares — the MoE layers here, the
    benchmarks, and the ``repro.tune`` pre-warm CLI, whose whole point is
    warming the exact plan keys (``n_out = n_experts * capacity``) that
    serving will look up."""
    mc = cfg.moe
    return max(1, int(mc.capacity_factor * tokens * mc.top_k / mc.n_experts))


def decode_capacity(cfg, batch: int) -> int:
    """Lossless per-expert capacity for a decode step: worst case every
    token routes to the same expert.  ``jax.lax.top_k`` expert ids are
    distinct per token, so one expert receives at most ONE assignment per
    token — capacity ``batch`` is lossless.  (The seed returned
    ``batch * top_k``, sizing the decode dispatch gather k times too big.)
    """
    return batch
