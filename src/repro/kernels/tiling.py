"""Shared tiling helpers for the Pallas rearrangement kernels.

TPU facts encoded here (v5e target):
* native vector register tile is (8, 128) for fp32 — (sublanes, lanes);
  bf16 packs (16, 128), int8 (32, 128).
* VMEM is ~16 MiB/core; the Pallas pipeline double-buffers every operand,
  so the *planner budget* is VMEM_BUDGET/2 per direction.
* DMA efficiency wants >= ~64 KiB per transfer; larger blocks amortize
  better until they crowd out double buffering.

The CUDA paper's 32x32 tile / 32x8 threads / 4-elements-per-thread choices
are the C1060 equivalents of exactly these constraints — see DESIGN.md §2.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax.numpy as jnp

# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 1024 * 1024
# pipeline double-buffers in + out; keep a conservative working budget
VMEM_BUDGET = VMEM_BYTES // 4

LANES = 128


def sublanes(dtype) -> int:
    """Minimum second-minor tile dim for a dtype (packing)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


def round_up(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m``."""
    return -(-x // m) * m


def cdiv(a: int, b: int) -> int:
    """Ceiling division (grid-step counts)."""
    return -(-a // b)


def pick_block(dim: int, target: int, mult: int) -> int:
    """Block size for one axis: ``target`` rounded to ``mult``, clamped to
    cover ``dim`` with no more padding waste than one partial block."""
    if dim <= mult:
        return dim  # tiny axis: single (possibly sub-tile) block
    b = min(round_up(target, mult), round_up(dim, mult))
    return b


@dataclass(frozen=True)
class TilePlan:
    """Chosen 2-D tile for the (rows, cols) movement plane."""

    block_r: int
    block_c: int
    grid_r: int
    grid_c: int

    @property
    def vmem_bytes_per_buf(self) -> int:
        """Elements per pipeline buffer (multiply by itemsize for bytes)."""
        return self.block_r * self.block_c


def plan_transpose_tiles(
    rows: int, cols: int, dtype, *, target: int | None = None
) -> TilePlan:
    """Tile the (rows, cols) transpose plane.

    Both the load block (br, bc) and the store block (bc, br) must be
    lane/sublane aligned, so *both* dims are rounded to LANES when large
    (a square 256x256 default keeps both sides full-width DMAs — the TPU
    version of "coalesced on read AND write", paper §III-B).
    """
    itemsize = jnp.dtype(dtype).itemsize
    if target is None:
        # in+out double-buffered: 4 buffers of br*bc*itemsize
        target = 256 if itemsize >= 2 else 512
        while 4 * target * target * itemsize > VMEM_BUDGET * 2:
            target //= 2
    br = pick_block(rows, target, LANES if rows >= LANES else sublanes(dtype))
    bc = pick_block(cols, target, LANES if cols >= LANES else sublanes(dtype))
    return TilePlan(br, bc, cdiv(rows, br), cdiv(cols, bc))


@dataclass(frozen=True)
class VecTilePlan:
    """Tile for the (rows, cols) transpose plane when every element carries
    a contiguous V-deep vector payload (collapsed identity tail)."""

    block_r: int
    block_c: int
    block_v: int
    grid_r: int
    grid_c: int
    grid_v: int


def plan_transpose_vec_tiles(rows: int, cols: int, vec: int, dtype) -> VecTilePlan:
    """Tile a batched (B, R, C, V) -> (B, C, R, V) transpose.

    V is the lane axis (it stays minor on both sides, so every DMA is a run
    of V-contiguous elements); R and C only need sublane alignment.  The
    whole payload is kept when it fits; otherwise V is blocked in LANES
    multiples and the (r, c) tile shrinks to respect the VMEM budget.
    """
    itemsize = jnp.dtype(dtype).itemsize
    sl = sublanes(dtype)
    budget_elems = max(VMEM_BUDGET // (2 * itemsize), 1)

    if vec <= LANES:
        bv = vec
    else:
        bv = min(round_up(vec, LANES), max(LANES, budget_elems // (sl * sl) // LANES * LANES))
        if bv > vec:
            bv = vec
    plane_budget = max(budget_elems // max(bv, 1), 1)
    target = max(int(plane_budget ** 0.5), 1)
    br = pick_block(rows, target, sl)
    bc = pick_block(cols, target, sl)
    while br * bc > plane_budget and bc > sl:
        bc = max(sl, bc // 2)
    while br * bc > plane_budget and br > sl:
        br = max(sl, br // 2)
    return VecTilePlan(
        br, bc, bv, cdiv(rows, br), cdiv(cols, bc), cdiv(vec, bv)
    )


def shrink_rows(br: int, bc: int, max_elems: int, sl: int) -> int:
    """Halve the row block until the (br, bc) buffer fits ``max_elems``,
    clamped at the ``sl`` sublane floor: plain halving can land below it
    (bf16 sl=16 with br=24 -> 12), producing an unaligned row block."""
    while br * bc > max_elems and br > sl:
        br = max(sl, br // 2)
    return br


def plan_copy_tiles(rows: int, cols: int, dtype, *, target_rows: int = 512) -> TilePlan:
    """Tile a streaming (rows, cols) copy: cols stay full-width when they
    fit the budget (long contiguous DMAs), rows are blocked."""
    itemsize = jnp.dtype(dtype).itemsize
    sl = sublanes(dtype)
    bc = cols
    max_elems = VMEM_BUDGET // (2 * itemsize)
    br = max(sl, min(round_up(target_rows, sl), max_elems // max(bc, 1)))
    if br > rows:
        br = rows
    br = shrink_rows(br, bc, max_elems, sl)
    return TilePlan(br, bc, cdiv(rows, br), cdiv(cols, bc))


def align_block(block: int, offset: int) -> int:
    """Largest block size <= ``block`` that divides evenly into ``offset``
    (halving search, floor 1).  Used when a window base offset must land on
    a block boundary so the BlockSpec index_map stays exact."""
    b = max(block, 1)
    while offset % b != 0:
        b //= 2
    return max(b, 1)


def force_interpret() -> bool:
    """Tests set REPRO_PALLAS_INTERPRET=1 to run kernels on CPU."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


# ---------------------------------------------------------------------------
# candidate enumeration (the autotuner's search space, DESIGN.md §11)
#
# Every planner's heuristic tile is one point in a small neighborhood of
# legal configurations; the tuner (core/tune.py) measures or cost-scores
# that neighborhood instead of trusting the one-shot formula.  Enumeration
# lives here so the legality rules (alignment, VMEM budget) stay next to
# the heuristics they relax.
# ---------------------------------------------------------------------------


def neighborhood(value: int, mult: int, dim: int) -> tuple[int, ...]:
    """The ±1 multiplier-step neighborhood of a block size over an axis of
    extent ``dim``: the heuristic ``value`` first (always kept verbatim, so
    the tuner's tie-break recovers the untuned plan exactly), then its
    halving and doubling, each aligned to ``mult`` and clamped to
    ``[mult, round_up(dim, mult)]``.  Axes at or below one ``mult`` tile
    have no neighbors (the heuristic already takes the whole axis)."""
    out = [value]
    if dim > mult:
        hi = round_up(dim, mult)
        for v in (value // 2, value * 2):
            v = max(mult, min(round_up(v, mult), hi))
            if v not in out:
                out.append(v)
    return tuple(out)


def transpose_tile_candidates(
    rows: int, cols: int, dtype, seed: TilePlan | None = None
) -> tuple[TilePlan, ...]:
    """Tile candidates for the transpose plane: the ``seed`` tile first
    (the analytic derivation when the planner recognized the request as
    affine, else the :func:`plan_transpose_tiles` heuristic), then its
    (block_r, block_c) ±1 neighborhood, keeping only VMEM-legal
    combinations (both the load and store blocks double-buffered)."""
    itemsize = jnp.dtype(dtype).itemsize
    base = seed if seed is not None else plan_transpose_tiles(rows, cols, dtype)
    mr = LANES if rows >= LANES else sublanes(dtype)
    mc = LANES if cols >= LANES else sublanes(dtype)
    out = []
    for br in neighborhood(base.block_r, mr, rows):
        for bc in neighborhood(base.block_c, mc, cols):
            if 4 * br * bc * itemsize > VMEM_BUDGET * 2:
                continue
            tp = TilePlan(br, bc, cdiv(rows, br), cdiv(cols, bc))
            if tp not in out:
                out.append(tp)
    return tuple(out) or (base,)


def vec_tile_candidates(
    rows: int, cols: int, vec: int, dtype, seed: VecTilePlan | None = None
) -> tuple[VecTilePlan, ...]:
    """Tile candidates for the V-deep transpose plane: the ``seed`` tile
    first (analytic derivation or the :func:`plan_transpose_vec_tiles`
    heuristic), then the (block_r, block_c) neighborhood at the seed's
    ``block_v`` (the lane-axis depth is fixed by payload contiguity, so
    only the plane tile is searched)."""
    itemsize = jnp.dtype(dtype).itemsize
    sl = sublanes(dtype)
    base = seed if seed is not None else plan_transpose_vec_tiles(rows, cols, vec, dtype)
    budget_elems = max(VMEM_BUDGET // (2 * itemsize), 1)
    plane_budget = max(budget_elems // max(base.block_v, 1), 1)
    out = []
    for br in neighborhood(base.block_r, sl, rows):
        for bc in neighborhood(base.block_c, sl, cols):
            if br * bc > plane_budget:
                continue
            vp = VecTilePlan(
                br, bc, base.block_v, cdiv(rows, br), cdiv(cols, bc),
                cdiv(vec, base.block_v),
            )
            if vp not in out:
                out.append(vp)
    return tuple(out) or (base,)


def copy_tile_candidates(
    rows: int, cols: int, dtype, seed: TilePlan | None = None
) -> tuple[TilePlan, ...]:
    """Tile candidates for the streaming-copy plane: columns stay full
    width (the long contiguous DMAs are the point of the route), only the
    row-block height is searched around the ``seed`` tile (analytic
    derivation or the :func:`plan_copy_tiles` heuristic)."""
    itemsize = jnp.dtype(dtype).itemsize
    sl = sublanes(dtype)
    base = seed if seed is not None else plan_copy_tiles(rows, cols, dtype)
    max_elems = VMEM_BUDGET // (2 * itemsize)
    out = []
    for br in neighborhood(base.block_r, sl, rows):
        br = min(br, rows)
        if br * base.block_c > max_elems:
            continue
        tp = TilePlan(br, base.block_c, cdiv(rows, br), cdiv(cols, base.block_c))
        if tp not in out:
            out.append(tp)
    return tuple(out) or (base,)


def row_block_candidates(
    base: int, n_out: int, row_bytes: int, dtype, top_k: int = 1
) -> tuple[int, ...]:
    """Row-block candidates for the index-set kernels: the IndexPlan
    heuristic height (``base``) first, then its ±1 step neighborhood, all
    sublane aligned and inside the double-buffered VMEM budget (divided by
    the combine fan-in ``top_k``, which keeps k source rows resident)."""
    sl = sublanes(dtype)
    br_budget = max(VMEM_BUDGET // (2 * max(row_bytes, 1) * top_k), 1)
    hi = min(max(br_budget // sl * sl, sl), max(n_out, 1))
    seen, out = set(), []
    for b in neighborhood(base, sl, hi):
        b = min(b, n_out)
        if b > 0 and b not in seen:
            seen.add(b)
            out.append(b)
    return tuple(out)
