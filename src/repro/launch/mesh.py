"""Production mesh construction (16x16 single pod / 2x16x16 multi-pod).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the dry-run's forced 512-device
initialization to happen first).
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    jax 0.4.37 lacks ``jax.sharding.AxisType`` (it landed in 0.5.x); on
    such builds the ``axis_types`` kwarg is omitted — every axis is Auto
    by default there, so semantics are identical.  All mesh construction
    in the repo (and the subprocess test harnesses) routes through this
    shim instead of touching ``AxisType`` directly.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def set_mesh_compat(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.sharding.set_mesh`` where present; on jax 0.4.37 the ``Mesh``
    object is itself the context manager (the legacy physical-mesh
    resource env), which is what explicit-sharding jits need there.
    """
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, across jax versions
    (0.4.37 ships it as ``jax.experimental.shard_map.shard_map`` with the
    ``check_rep`` spelling of ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def axis_size_compat(axis_name) -> int:
    """Static size of a named mapped axis, across jax versions
    (``jax.lax.axis_size`` is absent on 0.4.37, where
    ``jax.core.axis_frame(name)`` returns the size directly)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax import core as _core

    return _core.axis_frame(axis_name)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Degenerate mesh over whatever devices exist (smoke/e2e runs)."""
    n = len(jax.devices())
    return make_mesh_compat((n, 1), ("data", "model"))


def mesh_axes_info(mesh) -> dict:
    names = mesh.axis_names
    return {
        "model": "model",
        "data": "data",
        "model_size": mesh.shape["model"] if "model" in names else 1,
        "data_size": mesh.shape["data"] if "data" in names else 1,
        "pod_size": mesh.shape["pod"] if "pod" in names else 1,
        "multi_pod": "pod" in names,
    }


def batch_axes(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
