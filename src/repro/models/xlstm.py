"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent).

The mLSTM state update C_t = f C_{t-1} + i v k^T is itself a blocked
rearrangement + rank-1 update; state layout (B, H, d, d) keeps the lane
dim on the second d so both the update and the readout C q stay
lane-aligned (DESIGN.md §7).  The sLSTM recurrence is sequential by
construction — the paper's kernels apply to its state layout only.

Both train paths run a `lax.scan` over time (O(S) with compact HLO);
decode is the single-step body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.utils.scanutil import maybe_scan

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dt = cfg.np_dtype
    ks = jax.random.split(key, 6)
    return {
        "norm": common.norm_init(cfg.norm, d),
        "w_qkv": common.truncated_normal_init(ks[0], (d, 3 * d), 1.0, dt),
        "w_if": common.truncated_normal_init(ks[1], (d, 2 * h), 1.0, jnp.float32),
        "w_o_gate": common.truncated_normal_init(ks[2], (d, d), 1.0, dt),
        "w_out": common.truncated_normal_init(ks[3], (d, d), 1.0, dt),
    }


def _mlstm_step(carry, inp, dh: int):
    """carry: C (B,H,dh,dh), n (B,H,dh), m (B,H). inp: q,k,v (B,H,dh), i,f (B,H)."""
    C, n, m = carry
    q, k, v, ig, fg = inp
    logf = jax.nn.log_sigmoid(fg)  # (B,H)
    m_new = jnp.maximum(logf + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(logf + m - m_new)
    kf = k.astype(jnp.float32) * (dh ** -0.5)
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        v.astype(jnp.float32)[..., :, None] * kf[..., None, :]
    )
    n_new = f_p[..., None] * n + i_p[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf)), jnp.exp(-m_new))
    h_t = num / den[..., None]
    return (C_new, n_new, m_new), h_t


def _mlstm_inputs(p: dict, cfg, x: Array):
    b, s, d = x.shape
    hn = cfg.n_heads
    dh = d // hn
    h = common.apply_norm(cfg.norm, p["norm"], x)
    qkv = h @ p["w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    gates = (h.astype(jnp.float32) @ p["w_if"]).reshape(b, s, 2, hn)
    ig, fg = gates[:, :, 0], gates[:, :, 1]
    shp = (b, s, hn, dh)
    # recurrence runs data-parallel: replicate on 'model' before the scan
    from jax.sharding import PartitionSpec as P
    from repro.sharding.partition import BATCH, constrain
    rep = lambda a: constrain(a, P(BATCH, *([None] * (a.ndim - 1))))
    return h, rep(q.reshape(shp)), rep(k.reshape(shp)), rep(v.reshape(shp)), rep(ig), rep(fg)


def mlstm_apply(p: dict, cfg, x: Array, *, return_state: bool = False):
    b, s, d = x.shape
    hn = cfg.n_heads
    dh = d // hn
    h, q, k, v, ig, fg = _mlstm_inputs(p, cfg, x)
    # time-major for scan: (S, B, H, ...)
    tm = lambda a: jnp.moveaxis(a, 1, 0)
    C0 = jnp.zeros((b, hn, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, hn, dh), jnp.float32)
    m0 = jnp.full((b, hn), -1e30, jnp.float32)
    step = lambda c, i: _mlstm_step(c, i, dh)
    (C, n, m), hs = maybe_scan(
        step, (C0, n0, m0), (tm(q), tm(k), tm(v), tm(ig), tm(fg))
    )
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)  # (B,S,D)
    gated = hs * jax.nn.sigmoid(h @ p["w_o_gate"])
    out = x + gated @ p["w_out"]
    if return_state:
        return out, {"C": C, "n": n, "m": m}
    return out


def mlstm_init_state(cfg, batch: int) -> dict:
    hn = cfg.n_heads
    dh = cfg.d_model // hn
    return {
        "C": jnp.zeros((batch, hn, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, hn, dh), jnp.float32),
        "m": jnp.full((batch, hn), -1e30, jnp.float32),
    }


def mlstm_decode(p: dict, cfg, x1: Array, state: dict) -> tuple[Array, dict]:
    b, s, d = x1.shape  # s == 1
    hn = cfg.n_heads
    dh = d // hn
    h, q, k, v, ig, fg = _mlstm_inputs(p, cfg, x1)
    sq = lambda a: a[:, 0]
    (C, n, m), h_t = _mlstm_step(
        (state["C"], state["n"], state["m"]),
        (sq(q), sq(k), sq(v), sq(ig), sq(fg)),
        dh,
    )
    hs = h_t.reshape(b, 1, d).astype(x1.dtype)
    gated = hs * jax.nn.sigmoid(h @ p["w_o_gate"])
    return x1 + gated @ p["w_out"], {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    hn = cfg.n_heads
    dh = d // hn
    dt = cfg.np_dtype
    ks = jax.random.split(key, 3)
    return {
        "norm": common.norm_init(cfg.norm, d),
        "w_zifo": common.truncated_normal_init(ks[0], (d, 4 * d), 1.0, dt),
        # block-diagonal recurrent weights: per-head (dh, 4*dh)
        "r_zifo": common.truncated_normal_init(ks[1], (hn, dh, 4 * dh), 1.0, jnp.float32),
        "w_out": common.truncated_normal_init(ks[2], (d, d), 1.0, dt),
    }


def _slstm_step(p, cfg, carry, wx_t):
    """carry: h,c,n (B,H,dh), m (B,H,dh). wx_t: (B, 4D) pre-projected."""
    h_prev, c, n, m = carry
    b = h_prev.shape[0]
    hn = cfg.n_heads
    dh = cfg.d_model // hn
    rec = jnp.einsum("bhd,hde->bhe", h_prev, p["r_zifo"])  # (B,H,4dh)
    pre = wx_t.reshape(b, hn, 4 * dh).astype(jnp.float32) + rec
    z, ig, fg, og = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_apply(p: dict, cfg, x: Array, *, return_state: bool = False):
    b, s, d = x.shape
    hn = cfg.n_heads
    dh = d // hn
    h = common.apply_norm(cfg.norm, p["norm"], x)
    from jax.sharding import PartitionSpec as P
    from repro.sharding.partition import BATCH, constrain
    wx = constrain(h @ p["w_zifo"], P(BATCH, None, None))  # (B,S,4D) replicated-model
    carry0 = (
        jnp.zeros((b, hn, dh), jnp.float32),
        jnp.zeros((b, hn, dh), jnp.float32),
        jnp.zeros((b, hn, dh), jnp.float32),
        jnp.full((b, hn, dh), -1e30, jnp.float32),
    )
    step = lambda c, i: _slstm_step(p, cfg, c, i)
    (hf, cf, nf, mf), hs = maybe_scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = x + hs @ p["w_out"]
    if return_state:
        return out, {"h": hf, "c": cf, "n": nf, "m": mf}
    return out


def slstm_init_state(cfg, batch: int) -> dict:
    hn = cfg.n_heads
    dh = cfg.d_model // hn
    z = lambda: jnp.zeros((batch, hn, dh), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, hn, dh), -1e30, jnp.float32)}


def slstm_decode(p: dict, cfg, x1: Array, state: dict) -> tuple[Array, dict]:
    b, s, d = x1.shape
    h = common.apply_norm(cfg.norm, p["norm"], x1)
    wx = (h @ p["w_zifo"])[:, 0]
    carry = (state["h"], state["c"], state["n"], state["m"])
    (h_new, c, n, m), hs = _slstm_step(p, cfg, carry, wx)
    out = x1 + hs.reshape(b, 1, d).astype(x1.dtype) @ p["w_out"]
    return out, {"h": h_new, "c": c, "n": n, "m": m}
