"""Affine analytic planner: IR algebra, recognizer, closed-form tiles, ops.

Covers the acceptance surface of the affine refactor (DESIGN.md §14):
* AffineMap algebra: lift == jnp.transpose, compose . invert == identity,
  digit_split / from_window semantics, validation;
* the index-vector recognizer round-trips seeded shuffles (including
  rotated composite radixes) and refuses non-affine vectors;
* derive() reproduces the heuristic planner's tiles exactly for the
  permutation class — plans stamp `analytic` and stay the SAME object;
* the tuner's search space for affine-recognized requests is the analytic
  seed's ±1 neighborhood only (candidate count asserted), enumerated from
  the seed even when the heuristic formulas are unavailable;
* the plan_copy_tiles VMEM-shrink clamp stays sublane aligned (regression);
* the new ops (bit_reversal / strided_gather / diagonal_reorder / shuffle)
  match their jnp oracles for fp32 + bf16, ragged and zero-size shapes,
  and each compiles to exactly ONE pallas_call.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affine, layout
from repro.core import rearrange as rr
from repro.core.plan import (
    _affine_tile_candidates,
    _tile_candidates,
    plan_affine,
    plan_rearrange,
)
from repro.kernels import ops, ref, reorder_nd, tiling

RNG = np.random.default_rng(11)


def rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def n_pallas_calls(fn, *args) -> int:
    """Count pallas_call eqns anywhere in the traced jaxpr (incl. nested)."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call[")


# ---------------------------------------------------------------------------
# IR algebra
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "shape,perm",
    [
        ((3, 4), (1, 0)),
        ((2, 3, 4), (2, 0, 1)),
        ((2, 3, 4, 5), (0, 2, 1, 3)),
        ((1, 5, 1), (2, 1, 0)),
    ],
)
def test_lift_matches_transpose(shape, perm):
    amap = layout.to_affine(shape, perm)
    x = np.arange(int(np.prod(shape))).reshape(shape)
    np.testing.assert_array_equal(
        x.ravel()[amap.index_vector()], np.transpose(x, perm).ravel()
    )


@pytest.mark.parametrize(
    "make",
    [
        lambda: layout.to_affine((2, 3, 4), (2, 0, 1)),
        lambda: affine.bit_reversal_map((16, 5)),
        lambda: affine.diagonal_map((6, 8)),
        lambda: affine.shuffle_map(360, seed=3),
    ],
)
def test_compose_invert_is_identity(make):
    amap = make()
    ident = amap.compose(amap.invert())
    np.testing.assert_array_equal(ident.index_vector(), np.arange(amap.n_in))


def test_digit_split_preserves_semantics():
    amap = layout.to_affine((4, 6), (1, 0)).digit_split(0, (2, 3))
    assert amap.out_digits == (2, 3, 4)
    x = np.arange(24).reshape(4, 6)
    np.testing.assert_array_equal(
        x.ravel()[amap.index_vector()], x.T.ravel()
    )


def test_from_window_matches_sliced_transpose():
    amap = affine.AffineMap.from_window((8, 10), (2, 4), (3, 5), (1, 0))
    x = np.arange(80).reshape(8, 10)
    want = x[2:5, 4:9].T.ravel()
    np.testing.assert_array_equal(x.ravel()[amap.index_vector()], want)


def test_validation_rejects_bad_maps():
    with pytest.raises(ValueError):  # src not injective
        affine.AffineMap((2, 2), (2, 2), (0, 0), (0, 0), (0, 0), (-1, -1), (1, 1))
    with pytest.raises(ValueError):  # window exceeds radix
        affine.AffineMap.from_window((4, 4), (2, 0), (3, 4), (0, 1))
    with pytest.raises(ValueError):  # rot out of range
        affine.AffineMap((4,), (4,), (0,), (0,), (4,), (-1,), (1,))
    with pytest.raises(ValueError):  # only plain digits split
        affine.diagonal_map((4, 4)).digit_split(1, (2, 2))


# ---------------------------------------------------------------------------
# recognizer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,seed",
    [
        (12, 0),
        (360, 5),
        (1 << 10, 7),
        (3584, 1473368956),  # regression: radix-4 digit with odd rotation
        (97, 1),  # prime row count: rotation-only digit space
    ],
)
def test_recognizer_roundtrips_shuffles(n, seed):
    amap = affine.shuffle_map(n, seed=seed)
    iv = amap.index_vector()
    assert sorted(iv.tolist()) == list(range(n))
    rec = affine.recognize_index_vector(iv)
    assert rec is not None
    np.testing.assert_array_equal(rec.index_vector(), iv)


def test_recognizer_roundtrips_bit_reversal():
    iv = affine.bit_reversal_map((32,)).index_vector()
    rec = affine.recognize_index_vector(iv)
    assert rec is not None
    np.testing.assert_array_equal(rec.index_vector(), iv)


def test_recognizer_refuses_non_affine():
    idx = np.arange(64)
    idx[3], idx[17] = idx[17], idx[3]  # a lone transposition is not separable
    assert affine.recognize_index_vector(idx) is None
    bad = np.arange(16)
    bad[0] = bad[1]  # not a permutation
    assert affine.recognize_index_vector(bad) is None


# ---------------------------------------------------------------------------
# closed-form derivation == heuristic route (the SAME-object contract)
# ---------------------------------------------------------------------------

PERM_CASES = [
    ((5, 9), (1, 0)),
    ((3, 40, 50), (0, 2, 1)),
    ((8, 512, 16, 64), (0, 2, 1, 3)),
    ((4, 5, 6, 128), (2, 1, 0, 3)),
    ((7, 11, 13), (2, 1, 0)),
    ((1, 5, 1), (2, 1, 0)),
    ((0, 4, 8), (1, 0, 2)),
]


@pytest.mark.parametrize("shape,perm", PERM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_plans_are_same_object_and_stamped(shape, perm, dtype):
    p1 = plan_rearrange(shape, dtype, perm)
    p2 = plan_rearrange(shape, dtype, perm)
    assert p1 is p2  # lru identity: bit-identical is free
    assert p1.plan_source in ("heuristic", "analytic")
    if 0 not in shape and 1 not in shape:
        # every clean shape must derive analytically (closed-form == routed)
        assert p1.plan_source == "analytic"


@pytest.mark.parametrize("shape,perm", [c for c in PERM_CASES if 0 not in c[0]])
def test_derive_reproduces_heuristic_tiles(shape, perm):
    plan = plan_rearrange(shape, jnp.float32, perm)
    ex = affine.derive(layout.to_affine(shape, perm), "float32", "out")
    if plan.plan_source == "analytic":
        assert (ex.mode, ex.block_r, ex.block_c, ex.block_v, ex.exec_shape) == (
            plan.mode, plan.block_r, plan.block_c, plan.block_v, plan.exec_shape
        )


def test_describe_includes_tiles_exec_and_source():
    plan = plan_rearrange((8, 512, 16, 64), jnp.float32, (0, 2, 1, 3))
    s = plan.describe()
    assert f",{plan.block_v})" in s  # vec route: block_v rides in tiles=(..)
    assert f"exec={plan.exec_shape}" in s
    assert f"source={plan.plan_source}" in s


# ---------------------------------------------------------------------------
# tuner search space: analytic seed ± 1 neighborhood only
# ---------------------------------------------------------------------------


def test_candidates_enumerate_from_seed(monkeypatch):
    """The enumerators must expand the *seed* tile, not re-run the
    heuristic formulas: with the formulas disabled the seeded calls still
    enumerate, and the seed itself is candidate 0."""

    def boom(*a, **k):
        raise AssertionError("enumerator re-ran the heuristic formula")

    monkeypatch.setattr(tiling, "plan_transpose_tiles", boom)
    monkeypatch.setattr(tiling, "plan_transpose_vec_tiles", boom)
    monkeypatch.setattr(tiling, "plan_copy_tiles", boom)
    seed = tiling.TilePlan(256, 256, 2, 2)
    cands = tiling.transpose_tile_candidates(512, 512, jnp.float32, seed)
    assert cands[0] == seed
    cands = tiling.copy_tile_candidates(512, 512, jnp.float32, seed)
    assert cands[0].block_r == 256
    vseed = tiling.VecTilePlan(64, 64, 128, 8, 8, 1)
    vcands = tiling.vec_tile_candidates(512, 512, 128, jnp.float32, vseed)
    assert vcands[0] == vseed


@pytest.mark.parametrize("shape,perm", [c for c in PERM_CASES if 0 not in c[0]])
def test_search_space_is_seed_neighborhood(shape, perm):
    """Affine-recognized requests search only the analytic seed's ±1
    neighborhood: <= 3x3 tile pairs per grid-walk order."""
    plan = plan_rearrange(shape, jnp.float32, perm)
    if plan.mode == "identity":
        return
    cands = _tile_candidates(plan, shape, "float32", "out")
    orders = {dict(c.params)["grid_order"] for c in cands}
    assert len(cands) <= 9 * len(orders)
    assert dict(cands[0].params)["block_r"] == plan.block_r
    assert dict(cands[0].params)["block_c"] == plan.block_c


@pytest.mark.parametrize(
    "make",
    [
        lambda: affine.diagonal_map((256, 384)),
        lambda: affine.shuffle_map(4096, payload=(256,), seed=9),
        lambda: affine.strided_map((64, 256), axis=0, stride=4),
    ],
)
def test_affine_search_space_is_seed_neighborhood(make):
    plan = plan_affine(make(), jnp.float32)
    cands = _affine_tile_candidates(plan, "float32")
    assert 1 <= len(cands) <= 9
    assert dict(cands[0].params)["block_r"] == plan.block_r
    assert dict(cands[0].params)["block_c"] == plan.block_c


def test_affine_tuned_seed_win_keeps_object_identity():
    amap = affine.diagonal_map((256, 384))
    base = plan_affine(amap, jnp.float32, tuned=False)
    tuned = plan_affine(amap, jnp.float32, tuned=True)
    if tuned.plan_source == "analytic":
        assert tuned is base  # seed verified: SAME object as the untuned plan
    else:
        assert tuned.plan_source == "tuned"


def test_zero_radix_is_rejected_by_the_ir():
    # zero-size arrays never reach the IR: the ops guard on x.size and
    # dispatch to the oracle, and the map constructor rejects radix 0
    with pytest.raises(ValueError):
        layout.to_affine((0, 4), (1, 0))


# ---------------------------------------------------------------------------
# plan_copy_tiles clamp regression (the VMEM-shrink must stay aligned)
# ---------------------------------------------------------------------------


def test_copy_tiles_shrink_stays_sublane_aligned():
    # bf16: sl=16; br=24 over budget halves once.  Plain //2 gave 12
    # (unaligned); the clamp floors at the sublane count.
    assert tiling.shrink_rows(24, 43691, 1_048_576, 16) == 16
    assert tiling.shrink_rows(512, 43691, 1_048_576, 16) == 16
    assert tiling.shrink_rows(512, 1024, 1_048_576, 16) == 512  # fits: no-op
    # end to end: every copy-route row block is the whole axis or aligned
    # to (at least) the sublane floor
    sl = tiling.sublanes(jnp.bfloat16)
    for rows, cols in [(100, 4096), (4096, 512), (8, 100000), (1000, 131072)]:
        tp = tiling.plan_copy_tiles(rows, cols, jnp.bfloat16)
        assert tp.block_r == rows or tp.block_r >= sl


# ---------------------------------------------------------------------------
# the ops the planner unlocks (kernels in interpret mode)
# ---------------------------------------------------------------------------

OP_DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("dtype", OP_DTYPES)
@pytest.mark.parametrize("shape,axis", [((64, 128), 0), ((8, 32, 128), 1), ((16,), 0)])
def test_bit_reversal_matches_oracle(pallas_interpret, shape, axis, dtype):
    x = rand(shape, dtype)
    got = ops.bit_reversal(x, axis=axis)
    n = shape[axis]
    bits = n.bit_length() - 1
    rev = np.array(
        [int(format(i, f"0{bits}b")[::-1], 2) for i in range(n)]
    ) if bits else np.array([0])
    want = np.take(np.asarray(x), rev, axis=axis)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_bit_reversal_rejects_non_power_of_two(pallas_interpret):
    with pytest.raises(ValueError):
        ops.bit_reversal(rand((12, 8), jnp.float32))


@pytest.mark.parametrize("dtype", OP_DTYPES)
@pytest.mark.parametrize(
    "shape,axis,stride,phase",
    [((64, 128), 0, 4, 0), ((64, 128), 0, 4, 3), ((8, 30, 128), 1, 5, 2), ((63, 130), 1, 13, 7)],
)
def test_strided_gather_matches_oracle(pallas_interpret, shape, axis, stride, phase, dtype):
    x = rand(shape, dtype)
    got = ops.strided_gather(x, stride, phase=phase, axis=axis)
    idx = [slice(None)] * len(shape)
    idx[axis] = slice(phase, None, stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x)[tuple(idx)])


@pytest.mark.parametrize("dtype", OP_DTYPES)
@pytest.mark.parametrize("shape", [(64, 128), (4, 33, 130), (5, 7)])
def test_diagonal_reorder_matches_oracle(pallas_interpret, shape, dtype):
    x = rand(shape, dtype)
    got = np.asarray(ops.diagonal_reorder(x))
    xn = np.asarray(x)
    rows, cols = shape[-2], shape[-1]
    want = np.empty_like(xn)
    for i in range(rows):
        want[..., i, :] = xn[..., i, (i + np.arange(cols)) % cols]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", OP_DTYPES)
@pytest.mark.parametrize("shape", [(4096, 256), (360, 33), (97, 8), (1000,)])
def test_shuffle_matches_oracle_and_is_seeded(pallas_interpret, shape, dtype):
    x = rand(shape, dtype)
    got = ops.shuffle(x, seed=5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.shuffle(x, seed=5)))
    # bijective: sorting rows back recovers the multiset; same seed repeats
    np.testing.assert_array_equal(
        np.sort(np.asarray(got), axis=0), np.sort(np.asarray(x), axis=0)
    )
    again = ops.shuffle(x, seed=5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))
    other = ops.shuffle(x, seed=6)
    assert not np.array_equal(np.asarray(got), np.asarray(other))


@pytest.mark.parametrize(
    "fn",
    [
        lambda x: ops.bit_reversal(x, axis=1),
        lambda x: ops.strided_gather(x, 2, axis=1),
        lambda x: ops.diagonal_reorder(x),
        lambda x: ops.shuffle(x, seed=3),
    ],
)
def test_zero_size_inputs(pallas_interpret, fn):
    x = jnp.zeros((0, 8), jnp.float32)
    out = fn(x)
    assert out.shape[0] == 0


@pytest.mark.parametrize(
    "fn,shape",
    [
        (lambda x: ops.bit_reversal(x, axis=0), (64, 128)),
        (lambda x: ops.strided_gather(x, 4, phase=1, axis=0), (64, 128)),
        (lambda x: ops.diagonal_reorder(x), (64, 128)),
        (lambda x: ops.shuffle(x, seed=2), (360, 128)),
    ],
)
def test_new_ops_are_one_pallas_call(pallas_interpret, fn, shape):
    x = rand(shape, jnp.float32)
    assert n_pallas_calls(fn, x) == 1


def test_rearrange_wrappers_delegate(pallas_interpret):
    x = rand((32, 64), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(rr.bit_reversal(x)), np.asarray(ops.bit_reversal(x))
    )
    np.testing.assert_array_equal(
        np.asarray(rr.strided_gather(x, 2)), np.asarray(ops.strided_gather(x, 2))
    )
    np.testing.assert_array_equal(
        np.asarray(rr.diagonal_reorder(x)), np.asarray(ops.diagonal_reorder(x))
    )
    np.testing.assert_array_equal(
        np.asarray(rr.shuffle(x, seed=1)), np.asarray(ops.shuffle(x, seed=1))
    )


# ---------------------------------------------------------------------------
# reorder_affine kernel vs the index-vector oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", OP_DTYPES)
@pytest.mark.parametrize(
    "make",
    [
        lambda: affine.bit_reversal_map((64, 128)),
        lambda: affine.strided_map((64, 128), axis=0, stride=4, phase=2),
        lambda: affine.diagonal_map((48, 96)),
        lambda: affine.shuffle_map(720, payload=(32,), seed=4),
        lambda: affine.AffineMap.from_window((40, 64), (8, 0), (16, 64), (0, 1)),
    ],
)
def test_reorder_affine_matches_index_vector(pallas_interpret, make, dtype):
    amap = make()
    x = rand(amap.in_digits, dtype)
    got = reorder_nd.reorder_affine(x, amap, interpret=True)
    want = np.asarray(x).ravel()[amap.index_vector()].reshape(amap.out_digits)
    np.testing.assert_array_equal(np.asarray(got), want)
