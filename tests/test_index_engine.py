"""IndexPlan engine: block -> route -> cache (DESIGN.md §4).

Covers the acceptance surface of the index-set engine:
* oracle equivalence for the blocked masked gather, the capacity scatter,
  and the fused gather+weighted-combine — sentinel indices, contiguous-run
  inputs (the run-detection fast path), ragged/odd row counts and C,
  zero-size tables, fp32 + bf16;
* the MoE sort path lowers to exactly TWO `pallas_call`s (blocked
  dispatch gather + fused combine) with no sentinel-row concatenate in
  the jaxpr, and the plan engine is bit-identical to the seed row-wise
  path under jit;
* eager validation of the scatter contract;
* the plan cache returns the identical plan object on repeated calls
  (mirroring test_plan_engine.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index_plan import index_plan_cache_info, plan_index_op
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

DTYPES = [jnp.float32, jnp.bfloat16]


def rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def n_pallas_calls(fn, *args) -> int:
    """Count pallas_call eqns anywhere in the traced jaxpr (incl. nested)."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call[")


# ---------------------------------------------------------------------------
# routing / planning
# ---------------------------------------------------------------------------


def test_plan_routes_and_geometry():
    p = plan_index_op((1024, 512), jnp.bfloat16, 4096, "gather", masked=True)
    assert p.mode == "blocked" and p.kernel == "gather_rows_blocked"
    assert p.grid * p.block_rows >= p.n_out == 4096
    assert p.table_rows == p.grid * p.block_rows
    c = plan_index_op((4096, 512), jnp.bfloat16, 1024, "gather_combine", top_k=2)
    assert c.kernel == "gather_combine_blocked" and c.top_k == 2
    assert "MB moved" in p.describe() and "gather" in p.describe()


def test_plan_zero_size_routes_noop():
    assert plan_index_op((16, 128), jnp.float32, 0, "gather").mode == "noop"
    assert plan_index_op((16, 0), jnp.float32, 8, "gather").mode == "noop"
    assert plan_index_op((0, 128), jnp.float32, 8, "gather", masked=True).mode == "noop"


def test_plan_validates_inputs():
    with pytest.raises(ValueError, match="semantics"):
        plan_index_op((16, 128), jnp.float32, 8, "sideways")
    with pytest.raises(ValueError, match="2-D"):
        plan_index_op((16, 128, 2), jnp.float32, 8, "gather")
    with pytest.raises(ValueError, match="top_k"):
        plan_index_op((16, 128), jnp.float32, 8, "gather", top_k=0)


def test_plan_cache_returns_identical_object():
    a = plan_index_op((256, 128), jnp.bfloat16, 512, "gather", masked=True)
    b = plan_index_op((256, 128), jnp.bfloat16, 512, "gather", masked=True)
    assert a is b
    # dtype spellings normalize to the same key
    c = plan_index_op((256, 128), np.dtype("bfloat16"), 512, "gather", masked=True)
    assert c is a
    # semantics/top_k are part of the key
    d = plan_index_op((256, 128), jnp.bfloat16, 512, "scatter", masked=True)
    assert d is not a
    before = index_plan_cache_info().hits
    plan_index_op((256, 128), jnp.bfloat16, 512, "gather", masked=True)
    assert index_plan_cache_info().hits == before + 1


# ---------------------------------------------------------------------------
# blocked gather: oracle equivalence
# ---------------------------------------------------------------------------

GATHER_CASES = [
    # (n_src, C, idx builder) — sentinels, duplicates, runs, ragged sizes
    (64, 128, lambda n: RNG.integers(0, n, 64)),
    (37, 130, lambda n: RNG.integers(0, n, 101)),  # odd C, ragged n_out
    (64, 128, lambda n: np.concatenate([np.arange(n), [-1, 0, 0, n - 1]])),
    (16, 256, lambda n: np.full(40, -1)),  # all sentinels
    (200, 64, lambda n: np.arange(n)),  # pure contiguous run (fast path)
    (200, 64, lambda n: np.arange(5, 133)),  # misaligned run
    (8, 128, lambda n: RNG.integers(-1, n, 300)),  # n_out >> n_src
]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("case", range(len(GATHER_CASES)))
def test_masked_gather_matches_oracle(case, dtype, pallas_interpret):
    n_src, c, mk = GATHER_CASES[case]
    x = rand((n_src, c), dtype)
    idx = jnp.asarray(mk(n_src), jnp.int32)
    got = ops.gather_rows(x, idx, masked=True)
    want = ref.gather_rows_masked(x, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unmasked_gather_matches_take(pallas_interpret):
    x = rand((50, 160), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 50, 77), jnp.int32)
    got = ops.gather_rows(x, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x)[np.asarray(idx)])


def test_gather_zero_size_idx(pallas_interpret):
    x = rand((16, 128), jnp.float32)
    out = ops.gather_rows(x, jnp.zeros((0,), jnp.int32), masked=True)
    assert out.shape == (0, 128)


def test_gather_single_pallas_call(pallas_interpret):
    x = rand((64, 128), jnp.float32)
    idx = jnp.asarray(RNG.integers(-1, 64, 96), jnp.int32)
    assert n_pallas_calls(lambda a, i: ops.gather_rows(a, i, masked=True), x, idx) == 1


def test_rowwise_engine_still_available(pallas_interpret):
    x = rand((32, 128), jnp.float32)
    idx = jnp.asarray(RNG.permutation(32), jnp.int32)
    got = ops.gather_rows(x, idx, engine="rowwise")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x)[np.asarray(idx)])


# ---------------------------------------------------------------------------
# scatter: permutation + capacity forms, eager contract validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,c", [(16, 128), (37, 200)])
def test_scatter_permutation_matches_oracle(n, c, dtype, pallas_interpret):
    x = rand((n, c), dtype)
    idx = jnp.asarray(RNG.permutation(n), jnp.int32)
    got = ops.scatter_rows(x, idx)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.scatter_rows(x, idx))
    )


@pytest.mark.parametrize("n,num_out", [(16, 40), (37, 64), (8, 9)])
def test_capacity_scatter_zero_fills_dropped_slots(n, num_out, pallas_interpret):
    """num_out > n (capacity scatter): unmapped rows must be zero."""
    x = rand((n, 128), jnp.float32)
    targets = np.asarray(RNG.permutation(num_out)[:n], np.int32)
    got = ops.scatter_rows(x, jnp.asarray(targets), num_out=num_out)
    want = np.zeros((num_out, 128), np.float32)
    want[targets] = np.asarray(x)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert (
        n_pallas_calls(
            lambda a, i: ops.scatter_rows(a, i, num_out=num_out),
            x,
            jnp.asarray(targets),
        )
        == 1
    )


def test_scatter_contract_validated_eagerly(pallas_interpret):
    x = rand((16, 128), jnp.float32)
    with pytest.raises(ValueError, match="1-D idx"):
        ops.scatter_rows(x, jnp.zeros((16, 2), jnp.int32))
    with pytest.raises(ValueError, match="1-D idx"):
        ops.scatter_rows(x, jnp.zeros((8,), jnp.int32))  # wrong length
    with pytest.raises(ValueError, match="injective"):
        ops.scatter_rows(x, jnp.asarray(RNG.permutation(16), jnp.int32), num_out=8)


# ---------------------------------------------------------------------------
# fused gather + weighted combine
# ---------------------------------------------------------------------------

COMBINE_CASES = [
    (64, 128, 33, 2),  # ragged T
    (37, 130, 20, 3),  # odd C, odd k
    (16, 256, 50, 1),  # k = 1
    (128, 64, 8, 6),   # wide fan-in
]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n_src,c,t,k", COMBINE_CASES)
def test_gather_combine_matches_oracle(n_src, c, t, k, dtype, pallas_interpret):
    src = rand((n_src, c), dtype)
    back = jnp.asarray(RNG.integers(-1, n_src, (t, k)), jnp.int32)
    gates = jnp.asarray(RNG.standard_normal((t, k)), jnp.float32)
    got = jax.jit(ops.gather_combine)(src, back, gates)
    want = jax.jit(ref.gather_combine)(src, back, gates)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_combine_all_sentinels_is_zero(pallas_interpret):
    src = rand((16, 128), jnp.float32)
    back = jnp.full((9, 2), -1, jnp.int32)
    gates = jnp.ones((9, 2), jnp.float32)
    out = ops.gather_combine(src, back, gates)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((9, 128), np.float32))


def test_gather_combine_zero_tokens(pallas_interpret):
    src = rand((16, 128), jnp.float32)
    out = ops.gather_combine(
        src, jnp.zeros((0, 2), jnp.int32), jnp.zeros((0, 2), jnp.float32)
    )
    assert out.shape == (0, 128)


def test_gather_combine_single_pallas_call(pallas_interpret):
    src = rand((64, 128), jnp.float32)
    back = jnp.asarray(RNG.integers(-1, 64, (24, 2)), jnp.int32)
    gates = jnp.asarray(RNG.standard_normal((24, 2)), jnp.float32)
    assert n_pallas_calls(ops.gather_combine, src, back, gates) == 1


def test_gather_combine_validates_shapes(pallas_interpret):
    src = rand((16, 128), jnp.float32)
    with pytest.raises(ValueError, match="back/gates"):
        ops.gather_combine(
            src, jnp.zeros((4, 2), jnp.int32), jnp.zeros((4, 3), jnp.float32)
        )


# ---------------------------------------------------------------------------
# the MoE sort path through the engine
# ---------------------------------------------------------------------------


def _moe_setup():
    from repro import configs
    from repro.models import moe

    cfg = configs.get_config("deepseek-moe-16b-smoke")
    p = moe.moe_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(
        jax.random.PRNGKey(4), (2, 16, cfg.d_model), jnp.float32
    ).astype(cfg.np_dtype)
    cap = 2 * 16 * cfg.moe.top_k  # dropless
    return moe, cfg, p, x, cap


def test_moe_sort_two_pallas_calls_no_sentinel_concat(pallas_interpret):
    """Dispatch + combine must be exactly 2 kernels (blocked gather, fused
    combine) and the jaxpr must not concatenate sentinel rows."""
    moe, cfg, p, x, cap = _moe_setup()
    jaxpr = str(
        jax.make_jaxpr(lambda a: moe.moe_sort(p, cfg, a, capacity=cap)[0])(x)
    )
    assert jaxpr.count("pallas_call[") == 2
    assert jaxpr.count("concatenate") == 0


def test_moe_sort_plan_bit_identical_to_rowwise(pallas_interpret):
    moe, cfg, p, x, cap = _moe_setup()
    y_plan = jax.jit(
        lambda a: moe.moe_sort(p, cfg, a, capacity=cap, engine="plan")[0]
    )(x)
    y_row = jax.jit(
        lambda a: moe.moe_sort(p, cfg, a, capacity=cap, engine="rowwise")[0]
    )(x)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_row))


def test_moe_sort_rejects_unknown_engine():
    moe, cfg, p, x, cap = _moe_setup()
    with pytest.raises(ValueError, match="engine"):
        moe.moe_sort(p, cfg, x, engine="warp")


def test_moe_decode_capacity_is_lossless_and_tight():
    """top_k expert ids are distinct per token, so capacity == batch is
    lossless for a single decode step (the seed oversized it k-fold)."""
    from repro import configs
    from repro.models import moe

    cfg = configs.get_config("deepseek-moe-16b-smoke")
    assert moe.decode_capacity(cfg, 8) == 8
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model)).astype(
        cfg.np_dtype
    )
    tight, _ = moe.moe_sort(p, cfg, x, capacity=moe.decode_capacity(cfg, 8))
    loose, _ = moe.moe_sort(p, cfg, x, capacity=8 * cfg.moe.top_k)
    np.testing.assert_array_equal(np.asarray(tight), np.asarray(loose))
