"""Paper Table 2: generic reorder on 3-/4-/5-D data (paper's exact rows)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import layout
from repro.core.plan import plan_rearrange
from repro.kernels import ops


def rr_plan(shape, perm):
    return plan_rearrange(shape, jnp.float32, perm)


# (paper order vector, shape) — Table 2 rows
ROWS = [
    ([1, 0, 2], (256, 256, 256)),
    ([1, 0, 2, 3], (256, 256, 256, 1)),
    ([3, 2, 0, 1], (256, 256, 1, 256)),
    ([3, 0, 2, 1, 4], (256, 16, 1, 256, 16)),
]


def run() -> list[str]:
    out = []
    rng = np.random.default_rng(0)
    for order, shape in ROWS:
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        perm = layout.paper_order_to_perm(order)
        fn = jax.jit(lambda a, p=perm: ops.permute(a, p))
        t = time_fn(fn, x)
        canon = layout.canonicalize(shape, perm)
        plan = rr_plan(shape, perm)
        out.append(
            row(
                f"reorder_{'-'.join(map(str, order))}",
                t,
                2 * x.nbytes,
                f"[{plan.mode}, coalesced {len(canon.shape)}D]",
                plan_mode=plan.mode,
                kernel=plan.kernel,
                measured="pallas" if ops.use_pallas() else "xla_oracle",
            )
        )
    return out
