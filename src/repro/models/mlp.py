"""Dense MLP blocks (SwiGLU / GELU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common

Array = jax.Array


def mlp_init(key, cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.np_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "norm": common.norm_init(cfg.norm, d),
        "w_up": common.truncated_normal_init(k1, (d, f), 1.0, dt),
        "w_down": common.truncated_normal_init(k2, (f, d), 1.0, dt),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = common.truncated_normal_init(k3, (d, f), 1.0, dt)
    return p


def _hidden(p: dict, act: str, h: Array) -> Array:
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import BATCH, constrain

    def ff(y):  # Megatron column-parallel activations: ff dim on 'model'
        spec = P(*([BATCH] + [None] * (y.ndim - 2) + ["model"]))
        return constrain(y, spec)

    up = ff(h @ p["w_up"])
    if act == "swiglu":
        return jax.nn.silu(ff(h @ p["w_gate"])) * up
    if act == "geglu":
        return jax.nn.gelu(ff(h @ p["w_gate"])) * up
    if act == "relu2":
        r = jax.nn.relu(up)
        return r * r
    return jax.nn.gelu(up)


def mlp_apply(p: dict, cfg, x: Array) -> Array:
    from repro.sharding.partition import constrain, replicated_spec, residual_spec

    h = common.apply_norm(cfg.norm, p["norm"], x)
    if getattr(cfg, "sp", False):
        # SP: gather the (bf16) normed activations, scatter the output sum
        h = constrain(h, replicated_spec(x.ndim))
    out = _hidden(p, cfg.act, h) @ p["w_down"]  # row-parallel
    spec = residual_spec(cfg, x.ndim) if getattr(cfg, "sp", False) else None
    out = constrain(out, spec) if spec is not None else constrain(
        out, residual_spec(cfg, x.ndim)
    )
    return x + out


def mlp_apply_blockwise(
    p: dict, cfg, x: Array, *, chunk: int = 1024, policy=None
) -> Array:
    """Blockwise-parallel FFN (DESIGN.md §13): the sequence axis is cut
    into ``chunk`` blocks, each full norm->FFN->residual run under its own
    ``jax.checkpoint`` so the (B, chunk, d_ff) hidden tensor — the largest
    activation in the block — never exists for more than one chunk at a
    time on the backward pass.

    Bit-identical to :func:`mlp_apply`: every op is pointwise over the
    sequence axis (per-token norm, row-wise matmuls), so slicing the
    sequence does not change any row's reduction order.  ``policy`` is a
    resolved ``jax.checkpoint`` policy (``models.common.remat_policy``).
    """
    s = x.shape[1]
    c = min(chunk, s)
    fn = jax.checkpoint(lambda xc: mlp_apply(p, cfg, xc), policy=policy)
    outs = [fn(x[:, lo:lo + c]) for lo in range(0, s, c)]
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)


def ffn_only(p: dict, cfg, h: Array) -> Array:
    """The FFN body without norm/residual (used by MoE shared experts)."""
    return _hidden(p, cfg.act, h) @ p["w_down"]
