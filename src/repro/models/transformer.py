"""Model assembler: stacks block units into scanned stages, provides
init / forward / loss / prefill / decode for every assigned architecture
(decoder-only, enc-dec, VLM cross-attn, MoE, recurrent families).

HLO hygiene: layers are stacked and scanned (one block body per distinct
unit in the plan), loss is computed in sequence chunks (never a full
(B, S, V) logits tensor), and each scan body is rematerialized.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import common, mlp, moe, rglru, xlstm
from repro.sharding import partition
from repro.utils.scanutil import maybe_scan

Array = jax.Array

# ---------------------------------------------------------------------------
# block dispatch tables
# ---------------------------------------------------------------------------


def block_init(key, cfg, kind: str) -> dict:
    k1, k2 = jax.random.split(key)
    if kind in ("attn", "attn_dense", "local", "enc"):
        dff = cfg.d_ff_dense if (kind == "attn_dense" and cfg.d_ff_dense) else cfg.d_ff
        return {"attn": attn.attn_init(k1, cfg), "mlp": mlp.mlp_init(k2, cfg, d_ff=dff)}
    if kind == "attn_moe":
        return {"attn": attn.attn_init(k1, cfg), "moe": moe.moe_init(k2, cfg)}
    if kind == "xattn":
        return {"xattn": attn.xattn_init(k1, cfg), "mlp": mlp.mlp_init(k2, cfg)}
    if kind == "dec":
        k3, k4 = jax.random.split(k2)
        return {
            "attn": attn.attn_init(k1, cfg),
            "xattn": attn.xattn_init(k3, cfg),
            "mlp": mlp.mlp_init(k4, cfg),
        }
    if kind == "mlstm":
        return {"cell": xlstm.mlstm_init(k1, cfg)}
    if kind == "slstm":
        return {"cell": xlstm.slstm_init(k1, cfg)}
    if kind == "rglru":
        return {"cell": rglru.rglru_init(k1, cfg), "mlp": mlp.mlp_init(k2, cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _mlp(p: dict, cfg, x: Array) -> Array:
    """Dense-FFN dispatch for the training forward: the blockwise-parallel
    seq-chunked FFN (DESIGN.md §13) when ``cfg.blockwise``, else the
    monolithic :func:`repro.models.mlp.mlp_apply` (bit-identical)."""
    if getattr(cfg, "blockwise", False):
        return mlp.mlp_apply_blockwise(
            p, cfg, x, chunk=cfg.blockwise_chunk,
            policy=common.remat_policy(cfg.remat_policy),
        )
    return mlp.mlp_apply(p, cfg, x)


def block_apply(kind: str, p: dict, cfg, x: Array, src: Array | None) -> tuple[Array, Array]:
    """Training/eval forward for one block. Returns (x, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_dense"):
        x = attn.attn_apply(p["attn"], cfg, x, kind=cfg.attn_kind)
        return _mlp(p["mlp"], cfg, x), zero
    if kind == "local":
        x = attn.attn_apply(p["attn"], cfg, x, kind="local")
        return _mlp(p["mlp"], cfg, x), zero
    if kind == "enc":
        x = attn.attn_apply(p["attn"], cfg, x, kind="bidir")
        return _mlp(p["mlp"], cfg, x), zero
    if kind == "attn_moe":
        x = attn.attn_apply(p["attn"], cfg, x, kind=cfg.attn_kind)
        x, aux = moe.moe_apply(p["moe"], cfg, x)
        return x, aux
    if kind == "xattn":
        x = attn.xattn_apply(p["xattn"], cfg, x, src)
        return _mlp(p["mlp"], cfg, x), zero
    if kind == "dec":
        x = attn.attn_apply(p["attn"], cfg, x, kind="full")
        x = attn.xattn_apply(p["xattn"], cfg, x, src)
        return _mlp(p["mlp"], cfg, x), zero
    if kind == "mlstm":
        return xlstm.mlstm_apply(p["cell"], cfg, x), zero
    if kind == "slstm":
        return xlstm.slstm_apply(p["cell"], cfg, x), zero
    if kind == "rglru":
        x = rglru.rglru_apply(p["cell"], cfg, x)
        return _mlp(p["mlp"], cfg, x), zero
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# parameter init (stacked stages)
# ---------------------------------------------------------------------------


def _stage_init(key, cfg, unit: tuple[str, ...], count: int) -> dict:
    def unit_init(k):
        ks = jax.random.split(k, len(unit))
        return {f"b{i}": block_init(ks[i], cfg, kind) for i, kind in enumerate(unit)}

    keys = jax.random.split(key, count)
    return jax.vmap(unit_init)(keys)


def init_params(key, cfg) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": common.embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.np_dtype),
        "final_norm": common.norm_init(cfg.norm, cfg.d_model),
    }
    params["stages"] = [
        _stage_init(k, cfg, unit, count)
        for k, (unit, count) in zip(
            jax.random.split(keys[1], len(cfg.decoder_plan())), cfg.decoder_plan()
        )
    ]
    if not cfg.tie_embeddings:
        params["lm_head"] = common.truncated_normal_init(
            keys[2], (cfg.d_model, cfg.vocab), 1.0, cfg.np_dtype
        )
    if cfg.encoder_layers:
        params["encoder"] = {
            "stages": [
                _stage_init(k, cfg, unit, count)
                for k, (unit, count) in zip(
                    jax.random.split(keys[3], len(cfg.encoder_plan())),
                    cfg.encoder_plan(),
                )
            ],
            "final_norm": common.norm_init(cfg.norm, cfg.d_model),
        }
    return params


def abstract_params(cfg) -> Any:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# forward (training / full-sequence eval)
# ---------------------------------------------------------------------------


def _maybe_remat(body, cfg):
    """Wrap a scan body in ``jax.checkpoint`` under ``cfg.remat``, resolving
    the named ``cfg.remat_policy`` (``nothing_saveable`` — the default,
    matching plain ``jax.checkpoint`` — ``dots_saveable``, ...) through
    :func:`repro.models.common.remat_policy`."""
    if not cfg.remat:
        return body
    policy = common.remat_policy(getattr(cfg, "remat_policy", None))
    return jax.checkpoint(body, policy=policy)


def _run_stages(
    stages: list, plans, cfg, x: Array, src: Array | None, batch_spec: P | None
) -> tuple[Array, Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for stage_params, (unit, count) in zip(stages, plans):

        def body(carry, unit_params):
            h, aux = carry
            if batch_spec is not None:
                h = partition.constrain(h, batch_spec)
            for i, kind in enumerate(unit):
                h, a = block_apply(kind, unit_params[f"b{i}"], cfg, h, src)
                aux = aux + a
            return (h, aux), None

        body = _maybe_remat(body, cfg)
        (x, aux_total), _ = maybe_scan(body, (x, aux_total), stage_params)
    return x, aux_total


def forward(
    params: dict,
    cfg,
    tokens: Array,
    *,
    frontend: Array | None = None,
    batch_spec: P | None = None,
) -> tuple[Array, Array]:
    """tokens (B, S) [+ frontend (B, N, D) stub embeddings] -> hidden (B,S,D)."""
    x = common.embed(params["embed"], tokens).astype(cfg.np_dtype)
    if cfg.pos_embed == "sinusoidal":
        pos = common.sinusoidal_pos(jnp.arange(tokens.shape[1]), cfg.d_model)
        x = x + pos.astype(cfg.np_dtype)
    src = None
    if cfg.encoder_layers:
        if frontend is None:
            raise ValueError(f"{cfg.name} needs frontend embeddings (audio frames)")
        enc = frontend.astype(cfg.np_dtype)
        enc, _ = _run_stages(
            params["encoder"]["stages"], cfg.encoder_plan(), cfg, enc, None, batch_spec
        )
        src = common.apply_norm(cfg.norm, params["encoder"]["final_norm"], enc)
    elif cfg.n_frontend_tokens:
        if frontend is None:
            raise ValueError(f"{cfg.name} needs frontend embeddings (image patches)")
        src = frontend.astype(cfg.np_dtype)

    x, aux = _run_stages(params["stages"], cfg.decoder_plan(), cfg, x, src, batch_spec)
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    return x, aux


def _logits_chunk(params: dict, cfg, h: Array) -> Array:
    head = params.get("lm_head")
    return common.unembed(params["embed"], h, head)


def loss_fn(
    params: dict,
    cfg,
    tokens: Array,
    labels: Array,
    *,
    frontend: Array | None = None,
    batch_spec: P | None = None,
    aux_weight: float = 0.01,
) -> Array:
    """Chunked softmax cross-entropy (never materializes (B, S, V))."""
    h, aux = forward(
        params, cfg, tokens, frontend=frontend, batch_spec=batch_spec
    )
    # SP residual is sequence-sharded; gather once before loss chunking
    h = partition.constrain(h, partition.replicated_spec(3))
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk, s)
    n_chunks = s // chunk
    hc = h[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, d)
    lc = labels[:, : n_chunks * chunk].reshape(b, n_chunks, chunk)
    hc = jnp.moveaxis(hc, 1, 0)  # (n_chunks, B, chunk, D)
    lc = jnp.moveaxis(lc, 1, 0)

    def body(tot, xs):
        hj, lj = xs
        logits = _logits_chunk(params, cfg, hj)  # (B, chunk, V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lj[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    body = _maybe_remat(body, cfg)
    total, _ = maybe_scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    loss = total / (b * n_chunks * chunk)
    if cfg.moe is not None:
        loss = loss + aux_weight * aux
    return loss


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _cache_shape_for(kind: str, cfg, batch: int, s_max: int) -> dict:
    hd = cfg.head_dim_resolved
    hkv = cfg.n_kv_heads
    dt = cfg.np_dtype
    if kind in ("attn", "attn_dense", "attn_moe", "dec"):
        s_eff = min(s_max, cfg.window) if cfg.attn_kind == "swa" else s_max
        c = {
            "k": jnp.zeros((batch, hkv, s_eff, hd), dt),
            "v": jnp.zeros((batch, hkv, s_eff, hd), dt),
        }
        if kind == "dec":
            n_src = cfg.n_frontend_tokens or 1
            c["cross"] = {
                "k": jnp.zeros((batch, hkv, n_src, hd), dt),
                "v": jnp.zeros((batch, hkv, n_src, hd), dt),
            }
        return c
    if kind == "local":
        w = min(cfg.window, s_max)
        return {
            "k": jnp.zeros((batch, hkv, w, hd), dt),
            "v": jnp.zeros((batch, hkv, w, hd), dt),
        }
    if kind == "xattn":
        n_src = cfg.n_frontend_tokens or 1
        return {
            "k": jnp.zeros((batch, hkv, n_src, hd), dt),
            "v": jnp.zeros((batch, hkv, n_src, hd), dt),
        }
    if kind == "mlstm":
        return xlstm.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return xlstm.slstm_init_state(cfg, batch)
    if kind == "rglru":
        return rglru.rglru_init_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg, batch: int, s_max: int) -> list:
    """Stacked (per stage) decode caches."""
    caches = []
    for unit, count in cfg.decoder_plan():
        unit_cache = {
            f"b{i}": _cache_shape_for(kind, cfg, batch, s_max)
            for i, kind in enumerate(unit)
        }
        caches.append(
            jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (count,) + l.shape), unit_cache
            )
        )
    return caches


def block_decode(
    kind: str, p: dict, cfg, x1: Array, cache: dict, pos: Array, src: Array | None
) -> tuple[Array, dict]:
    if kind in ("attn", "attn_dense", "attn_moe", "dec"):
        akind = "swa" if cfg.attn_kind == "swa" else "full"
        sub = {k: cache[k] for k in ("k", "v")}
        x1, sub = attn.attn_decode(p["attn"], cfg, x1, sub, pos, kind=akind)
        new = dict(cache)
        new.update(sub)
        if kind == "dec":
            x1 = attn.xattn_decode(p["xattn"], cfg, x1, cache["cross"])
        if kind == "attn_moe":
            x1, _ = moe.moe_apply(
                p["moe"], cfg, x1, capacity=moe.decode_capacity(cfg, x1.shape[0])
            )
        else:
            x1 = mlp.mlp_apply(p["mlp"], cfg, x1)
        return x1, new
    if kind == "local":
        x1, new = attn.attn_decode(p["attn"], cfg, x1, cache, pos, kind="local")
        return mlp.mlp_apply(p["mlp"], cfg, x1), new
    if kind == "xattn":
        x1 = attn.xattn_decode(p["xattn"], cfg, x1, cache)
        return mlp.mlp_apply(p["mlp"], cfg, x1), cache
    if kind == "mlstm":
        return xlstm.mlstm_decode(p["cell"], cfg, x1, cache)
    if kind == "slstm":
        return xlstm.slstm_decode(p["cell"], cfg, x1, cache)
    if kind == "rglru":
        x1, new = rglru.rglru_decode(p["cell"], cfg, x1, cache)
        return mlp.mlp_apply(p["mlp"], cfg, x1), new
    raise ValueError(kind)


def decode_step(
    params: dict,
    cfg,
    token: Array,  # (B,) int32
    cache: list,
    pos: Array,  # int32 absolute position: scalar, or (B,) per slot
    *,
    frontend_src: Array | None = None,
    batch_spec: P | None = None,
) -> tuple[Array, list]:
    """One serving step: next-token logits + updated cache.

    ``pos`` may be a scalar (all slots at the same position, the seed
    path) or a (B,) per-slot vector — the continuous-batching engine's
    layout, threaded through to the attention ring writes and per-slot
    length masks (DESIGN.md §12)."""
    pos = jnp.asarray(pos)
    x = common.embed(params["embed"], token[:, None]).astype(cfg.np_dtype)
    if cfg.pos_embed == "sinusoidal":
        pv = pos[None] if pos.ndim == 0 else pos[:, None]
        x = x + common.sinusoidal_pos(pv, cfg.d_model).astype(cfg.np_dtype)
    src = frontend_src
    new_caches = []
    for stage_params, stage_cache, (unit, count) in zip(
        params["stages"], cache, cfg.decoder_plan()
    ):

        def body(h, xs):
            unit_params, unit_cache = xs
            if batch_spec is not None:
                h = partition.constrain(h, batch_spec)
            new_unit = {}
            for i, kind in enumerate(unit):
                h, new_unit[f"b{i}"] = block_decode(
                    kind, unit_params[f"b{i}"], cfg, h, unit_cache[f"b{i}"], pos, src
                )
            return h, new_unit

        x, new_stage = maybe_scan(body, x, (stage_params, stage_cache))
        new_caches.append(new_stage)
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits_chunk(params, cfg, x)[:, 0]  # (B, V)
    return logits, new_caches


def prefill(
    params: dict,
    cfg,
    tokens: Array,
    *,
    frontend: Array | None = None,
    batch_spec: P | None = None,
) -> tuple[Array, list]:
    """Process a prompt, return (last-token logits, decode cache)."""
    x = common.embed(params["embed"], tokens).astype(cfg.np_dtype)
    b, s = tokens.shape
    if cfg.pos_embed == "sinusoidal":
        x = x + common.sinusoidal_pos(jnp.arange(s), cfg.d_model).astype(cfg.np_dtype)
    src = None
    if cfg.encoder_layers:
        enc = frontend.astype(cfg.np_dtype)
        enc, _ = _run_stages(
            params["encoder"]["stages"], cfg.encoder_plan(), cfg, enc, None, batch_spec
        )
        src = common.apply_norm(cfg.norm, params["encoder"]["final_norm"], enc)
    elif cfg.n_frontend_tokens:
        src = frontend.astype(cfg.np_dtype) if frontend is not None else None

    caches = []
    for stage_params, (unit, count) in zip(params["stages"], cfg.decoder_plan()):

        def body(h, unit_params):
            if batch_spec is not None:
                h = partition.constrain(h, batch_spec)
            unit_cache = {}
            for i, kind in enumerate(unit):
                h, unit_cache[f"b{i}"] = _block_prefill(
                    kind, unit_params[f"b{i}"], cfg, h, src
                )
            return h, unit_cache

        body = _maybe_remat(body, cfg)
        x, stage_cache = maybe_scan(body, x, stage_params)
        caches.append(stage_cache)
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits_chunk(params, cfg, x[:, -1:])[:, 0]
    return logits, caches


def _block_prefill(kind: str, p: dict, cfg, x: Array, src) -> tuple[Array, dict]:
    if kind in ("attn", "attn_dense", "attn_moe", "dec", "local"):
        akind = (
            "local"
            if kind == "local"
            else ("swa" if cfg.attn_kind == "swa" else "full")
        )
        x, kv = attn.attn_prefill(p["attn"], cfg, x, kind=akind)
        if akind in ("swa", "local"):
            # keep only the window, laid out as the decode ring buffer:
            # token t lives at slot t % w
            w = cfg.window
            s = kv["k"].shape[2]
            if s > w:
                shift = (s - w) % w
                kv = {
                    k: jnp.roll(v[:, :, -w:], shift, axis=2) for k, v in kv.items()
                }
        if kind == "dec":
            x = attn.xattn_apply(p["xattn"], cfg, x, src)
            kv["cross"] = attn.xattn_cache(p["xattn"], cfg, src)
        if kind == "attn_moe":
            x, _ = moe.moe_apply(p["moe"], cfg, x)
        else:
            x = mlp.mlp_apply(p["mlp"], cfg, x)
        return x, kv
    if kind == "xattn":
        cache = attn.xattn_cache(p["xattn"], cfg, src)
        x = attn.xattn_apply(p["xattn"], cfg, x, src)
        return mlp.mlp_apply(p["mlp"], cfg, x), cache
    if kind == "mlstm":
        y, state = xlstm.mlstm_apply(p["cell"], cfg, x, return_state=True)
        return y, state
    if kind == "slstm":
        y, state = xlstm.slstm_apply(p["cell"], cfg, x, return_state=True)
        return y, state
    if kind == "rglru":
        y, state = rglru.rglru_apply(p["cell"], cfg, x, return_state=True)
        return mlp.mlp_apply(p["mlp"], cfg, y), state
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# serving: ragged packed prefill + chunked prefill (DESIGN.md §12)
# ---------------------------------------------------------------------------

#: block kinds the packed/chunked serving prefills support: plain causal
#: attention blocks only — recurrent state and window rings carry context
#: across the packed axis and cannot be segment-masked.
ATTN_ONLY_KINDS = ("attn", "attn_dense", "attn_moe")


def supports_ragged(cfg) -> bool:
    """True when ``cfg`` can take the packed ragged / chunked prefill
    routes: every decoder block is a full-attention kind and there is no
    encoder/frontend stream (segment masks don't reach those paths)."""
    kinds = {k for unit, _ in cfg.decoder_plan() for k in unit}
    return (
        kinds <= set(ATTN_ONLY_KINDS)
        and cfg.attn_kind != "swa"
        and not cfg.encoder_layers
        and not cfg.n_frontend_tokens
    )


def _block_prefill_ragged(
    kind: str, p: dict, cfg, x: Array, positions: Array, seg_ids: Array
) -> tuple[Array, dict]:
    if kind not in ATTN_ONLY_KINDS:
        raise ValueError(f"ragged prefill supports attention blocks only, got {kind!r}")
    x, kv = attn.attn_prefill(
        p["attn"], cfg, x, kind="full", positions=positions, seg_ids=seg_ids
    )
    if kind == "attn_moe":
        x, _ = moe.moe_apply(p["moe"], cfg, x)
    else:
        x = mlp.mlp_apply(p["mlp"], cfg, x)
    return x, kv


def prefill_ragged(
    params: dict,
    cfg,
    tokens: Array,  # (1, T) packed prompts
    seg_ids: Array,  # (T,) int32 sequence id per token, -1 for padding
    positions: Array,  # (T,) int32 within-sequence positions
    last_ix: Array,  # (n_seq,) packed index of each sequence's last token
    *,
    batch_spec: P | None = None,
) -> tuple[Array, list]:
    """Packed ragged prefill: several prompts share ONE prefill batch in a
    ``qo_indptr``-style layout (`core.index_plan.ragged_layout`); attention
    is segment-masked block-diagonal causal.  Returns (per-sequence
    last-token logits (n_seq, V), packed caches whose KV rows sit in packed
    order — the engine's ragged_rows IndexPlan gather unpacks them into the
    decode slots)."""
    x = common.embed(params["embed"], tokens).astype(cfg.np_dtype)
    if cfg.pos_embed == "sinusoidal":
        x = x + common.sinusoidal_pos(positions, cfg.d_model).astype(cfg.np_dtype)
    caches = []
    for stage_params, (unit, count) in zip(params["stages"], cfg.decoder_plan()):

        def body(h, unit_params):
            if batch_spec is not None:
                h = partition.constrain(h, batch_spec)
            unit_cache = {}
            for i, kind in enumerate(unit):
                h, unit_cache[f"b{i}"] = _block_prefill_ragged(
                    kind, unit_params[f"b{i}"], cfg, h, positions, seg_ids
                )
            return h, unit_cache

        body = _maybe_remat(body, cfg)
        x, stage_cache = maybe_scan(body, x, stage_params)
        caches.append(stage_cache)
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    h_last = x[0, last_ix]  # (n_seq, D)
    logits = _logits_chunk(params, cfg, h_last[:, None])[:, 0]
    return logits, caches


def _block_prefill_chunk(
    kind: str, p: dict, cfg, x: Array, cache: dict, pos: Array, active: Array
) -> tuple[Array, dict]:
    if kind not in ATTN_ONLY_KINDS:
        raise ValueError(f"chunked prefill supports attention blocks only, got {kind!r}")
    sub = {k: cache[k] for k in ("k", "v")}
    x, sub = attn.attn_prefill_chunk(p["attn"], cfg, x, sub, pos, active)
    new = dict(cache)
    new.update(sub)
    if kind == "attn_moe":
        x, _ = moe.moe_apply(p["moe"], cfg, x)
    else:
        x = mlp.mlp_apply(p["mlp"], cfg, x)
    return x, new


def prefill_chunk(
    params: dict,
    cfg,
    tokens: Array,  # (B, C) chunk of prompt tokens per slot
    cache: list,
    pos: Array,  # (B,) valid ring rows per slot before this chunk
    active: Array,  # (B,) bool: slots taking a chunk this step
    last_ix: Array,  # (B,) index of each slot's last real token in the chunk
    *,
    batch_spec: P | None = None,
) -> tuple[Array, list]:
    """Advance chunked prefill by one C-token chunk per active slot,
    writing KV rows at ``[pos, pos+C)`` directly into the engine cache
    (inactive slots' caches pass through untouched).  Returns (logits at
    each slot's ``last_ix`` chunk row, updated cache) — the logits matter
    only for slots whose prompt ends inside this chunk."""
    pos = jnp.asarray(pos)
    x = common.embed(params["embed"], tokens).astype(cfg.np_dtype)
    if cfg.pos_embed == "sinusoidal":
        positions = pos[:, None] + jnp.arange(tokens.shape[1])[None, :]
        x = x + common.sinusoidal_pos(positions, cfg.d_model).astype(cfg.np_dtype)
    new_caches = []
    for stage_params, stage_cache, (unit, count) in zip(
        params["stages"], cache, cfg.decoder_plan()
    ):

        def body(h, xs):
            unit_params, unit_cache = xs
            if batch_spec is not None:
                h = partition.constrain(h, batch_spec)
            new_unit = {}
            for i, kind in enumerate(unit):
                h, new_unit[f"b{i}"] = _block_prefill_chunk(
                    kind, unit_params[f"b{i}"], cfg, h, unit_cache[f"b{i}"],
                    pos, active,
                )
            return h, new_unit

        x, new_stage = maybe_scan(body, x, (stage_params, stage_cache))
        new_caches.append(new_stage)
    x = common.apply_norm(cfg.norm, params["final_norm"], x)
    h_last = jnp.take_along_axis(x, last_ix[:, None, None], axis=1)  # (B,1,D)
    logits = _logits_chunk(params, cfg, h_last)[:, 0]
    return logits, new_caches
