"""Continuous-batching serving engine over the ring-buffer KV caches.

The engine owns B fixed slots and runs three planned hot-path routes
(DESIGN.md §12):

* **ragged admission** — every admission wave packs the pending prompts
  into ONE ``qo_indptr``-style prefill batch (`core.index_plan.ragged_layout`
  + `models.transformer.prefill_ragged`); the packed KV rows are unpacked
  into the decode slots by a ``ragged_rows`` IndexPlan gather, so multiple
  prompts cost one forward instead of one forward each.
* **chunked prefill interleaved with decode** — with ``chunk`` set, long
  prompts are consumed ``chunk`` tokens per engine step
  (`models.transformer.prefill_chunk`) while the other slots keep
  decoding, so a long prompt never stalls live traffic.
* **per-slot positions** — decode threads a (B,) position vector through
  `models.transformer.decode_step`, so each slot masks its own ring
  length (admitted-late slots no longer attend rows beyond their prompt);
  on kernel backends the decode attention is the split-KV
  `kernels.flash.flash_decode` two-stage reduce.

Static shapes throughout: one compiled ragged prefill per packed width,
one compiled chunk step, one compiled decode.  The seed's left-padded
bucket prefill survives as ``prefill_mode="bucket"`` — the measured
baseline in ``benchmarks/bench_serve.py`` and the only route for
architectures whose blocks cannot be segment-masked (recurrent state,
sliding windows: see `models.transformer.supports_ragged`).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index_plan as ip
from repro.kernels import ops
from repro.models import transformer as tf

Array = jax.Array


@dataclass
class Request:
    """One serving request: a prompt in, greedy-decoded tokens out."""

    rid: int  #: caller-chosen request id
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32  #: tokens to emit (the prefill's first token counts)
    out: list = field(default_factory=list)  #: emitted token ids
    done: bool = False
    slot: int | None = None  #: engine slot while live (admission placement)


class Engine:
    """Slot-based continuous batching: admit into free slots, decode all
    live slots per step, reuse slots the moment a request finishes."""

    def __init__(self, cfg, params, *, batch_slots: int = 4, s_max: int = 256,
                 prompt_bucket: int = 64, prefill_mode: str | None = None,
                 chunk: int | None = None):
        """``prefill_mode`` is ``"ragged"`` (packed admission waves),
        ``"bucket"`` (the seed's one-row left-padded prefill) or ``None``
        to pick ragged whenever the architecture supports it.  ``chunk``
        (ragged mode only) caps the tokens prefilled per engine step:
        admission packs the first ``chunk`` prompt tokens, the remainder
        streams through `models.transformer.prefill_chunk` interleaved
        with decode."""
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.bucket = prompt_bucket
        ragged_ok = tf.supports_ragged(cfg)
        if prefill_mode is None:
            prefill_mode = "ragged" if ragged_ok else "bucket"
        if prefill_mode not in ("ragged", "bucket"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if prefill_mode == "ragged" and not ragged_ok:
            raise ValueError(
                "prefill_mode='ragged' needs attention-only decoder blocks "
                "(models.transformer.supports_ragged)"
            )
        if chunk is not None and prefill_mode != "ragged":
            raise ValueError("chunked prefill rides the ragged route only")
        if chunk is not None and chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.mode = prefill_mode
        self.chunk = chunk
        self.cache = tf.init_cache(cfg, batch_slots, s_max)
        self.pos = np.zeros(batch_slots, np.int32)  # per-slot next ring row
        self.off = np.zeros(batch_slots, np.int64)  # per-slot prompt cursor
        self.chunking = np.zeros(batch_slots, bool)  # slots still prefilling
        self.live: list[Request | None] = [None] * batch_slots
        self.frontend = None
        self._finished: list[Request] = []  # done at admission, drained by step
        self._decode = jax.jit(
            lambda p, tok, cache, pos: tf.decode_step(p, cfg, tok, cache, pos)
        )
        self._prefill = jax.jit(lambda p, toks: tf.prefill(p, cfg, toks))
        self._prefill_ragged = jax.jit(
            lambda p, toks, seg, pos, last: tf.prefill_ragged(
                p, cfg, toks, seg, pos, last
            )
        )
        self._prefill_chunk = jax.jit(
            lambda p, toks, cache, pos, active, last: tf.prefill_chunk(
                p, cfg, toks, cache, pos, active, last
            )
        )

    # -- admission -----------------------------------------------------------

    def free_slots(self) -> list[int]:
        """Indices of currently unoccupied slots."""
        return [i for i, r in enumerate(self.live) if r is None]

    def admit(self, req: Request) -> int | None:
        """Admit one request; returns its slot, or ``None`` when full."""
        slots = self.admit_batch([req])
        return slots[0] if slots else None

    def admit_batch(self, reqs: list[Request]) -> list[int]:
        """Admit up to ``len(free slots)`` requests in one wave; in ragged
        mode the whole wave shares ONE packed prefill.  Returns the chosen
        slot per admitted request (prefix of ``reqs``)."""
        free = self.free_slots()
        reqs = reqs[: len(free)]
        if not reqs:
            return []
        for r in reqs:
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.rid}: empty prompt")
            if len(r.prompt) >= self.s_max:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) does not fit "
                    f"the slot ring (s_max={self.s_max})"
                )
        slots = free[: len(reqs)]
        if self.mode == "ragged":
            self._admit_ragged(reqs, slots)
        else:
            for r, s in zip(reqs, slots):
                self._admit_bucket(r, s)
        return slots

    def _admit_ragged(self, reqs: list[Request], slots: list[int]) -> None:
        """Packed admission: prefill the head of every prompt (all of it,
        or the first ``chunk`` tokens) in one ragged batch and gather the
        packed KV rows into the slots."""
        heads = [
            min(len(r.prompt), self.chunk) if self.chunk else len(r.prompt)
            for r in reqs
        ]
        lay = ip.ragged_layout(tuple(heads), self.bucket)
        toks = np.zeros((1, lay.t_pad), np.int32)
        for j, r in enumerate(reqs):
            toks[0, lay.indptr[j] : lay.indptr[j] + heads[j]] = r.prompt[: heads[j]]
        last = np.zeros((self.b,), np.int32)  # padded to B: stable jit shape
        last[: len(reqs)] = lay.last_ix
        logits, packed = self._prefill_ragged(
            self.params,
            jnp.asarray(toks),
            jnp.asarray(lay.seg_ids),
            jnp.asarray(lay.positions),
            jnp.asarray(last),
        )
        self.cache = _write_ragged(self.cache, packed, slots, lay, self.s_max)
        lg = np.asarray(logits)
        for j, (r, s) in enumerate(zip(reqs, slots)):
            r.slot = s
            self.live[s] = r
            self.pos[s] = heads[j]
            self.off[s] = heads[j]
            self.chunking[s] = heads[j] < len(r.prompt)
            if not self.chunking[s]:
                self._emit(s, int(np.argmax(lg[j])))

    def _admit_bucket(self, req: Request, slot: int) -> None:
        """The seed route: one left-padded bucket prefill per request."""
        s = len(req.prompt)
        pad = -(-s // self.bucket) * self.bucket
        if pad > self.s_max:
            raise ValueError(
                f"request {req.rid}: prompt bucket ({pad}) exceeds s_max "
                f"({self.s_max})"
            )
        toks = np.zeros((1, pad), np.int32)
        toks[0, pad - s :] = req.prompt  # left-pad into the bucket
        logits, cache1 = self._prefill(self.params, jnp.asarray(toks))
        # copy the single-row cache into the slot (KV rows land at [0, pad))
        self.cache = _write_slot(self.cache, cache1, slot, self.s_max)
        req.slot = slot
        self.live[slot] = req
        self.pos[slot] = pad
        self.off[slot] = s
        self.chunking[slot] = False
        self._emit(slot, int(np.argmax(np.asarray(logits)[0])))

    def _emit(self, slot: int, token: int) -> None:
        """Record one generated token for ``slot``; retire the request when
        it hits ``max_new`` or its ring is full."""
        r = self.live[slot]
        r.out.append(token)
        if len(r.out) >= r.max_new or self.pos[slot] >= self.s_max:
            r.done = True
            r.slot = None
            self.live[slot] = None
            self.chunking[slot] = False
            self._finished.append(r)

    # -- stepping ------------------------------------------------------------

    def _chunk_wave(self) -> None:
        """Advance every still-prefilling slot by one ``chunk``-token wave
        (inactive slots' caches pass through untouched); slots whose
        prompt completes emit their first token and start decoding."""
        slots = [i for i in range(self.b) if self.live[i] is not None and self.chunking[i]]
        if not slots:
            return
        c = self.chunk
        toks = np.zeros((self.b, c), np.int32)
        active = np.zeros((self.b,), bool)
        last = np.zeros((self.b,), np.int32)
        counts: dict[int, int] = {}
        for i in slots:
            r = self.live[i]
            off = int(self.off[i])
            n = min(c, len(r.prompt) - off)
            toks[i, :n] = r.prompt[off : off + n]
            active[i] = True
            last[i] = n - 1
            counts[i] = n
        logits, self.cache = self._prefill_chunk(
            self.params,
            jnp.asarray(toks),
            self.cache,
            jnp.asarray(self.pos),
            jnp.asarray(active),
            jnp.asarray(last),
        )
        lg = np.asarray(logits)
        for i in slots:
            r = self.live[i]
            self.off[i] += counts[i]
            self.pos[i] += counts[i]
            if int(self.off[i]) == len(r.prompt):
                self.chunking[i] = False
                self._emit(i, int(np.argmax(lg[i])))

    def step(self) -> list[Request]:
        """One engine step: a chunk wave for prefilling slots, then one
        decoded token for every live decoding slot (per-slot positions).
        Returns the requests that finished during this step."""
        self._chunk_wave()
        finished, self._finished = self._finished, []
        decode_ix = [
            i for i, r in enumerate(self.live)
            if r is not None and not self.chunking[i]
        ]
        if decode_ix:
            toks = np.zeros((self.b,), np.int32)
            for i in decode_ix:
                toks[i] = self.live[i].out[-1]
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache, jnp.asarray(self.pos)
            )
            lg = np.asarray(logits)
            for i in decode_ix:
                self.pos[i] += 1
                self._emit(i, int(np.argmax(lg[i])))
            finished.extend(self._finished)
            self._finished = []
        return finished

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion; returns them in completion
        order (no per-step re-scan of the request list)."""
        pending = deque(requests)
        done: list[Request] = []
        while pending or any(r is not None for r in self.live):
            n_free = len(self.free_slots())
            if pending and n_free:
                wave = [pending.popleft() for _ in range(min(n_free, len(pending)))]
                self.admit_batch(wave)
            done.extend(self.step())
        return done

    def reset(self) -> None:
        """Drop all slot state (cache contents, positions, live requests)
        while keeping the compiled steps — benchmarks replay traces on one
        engine instance so jit caches stay warm."""
        self.cache = tf.init_cache(self.cfg, self.b, self.s_max)
        self.pos[:] = 0
        self.off[:] = 0
        self.chunking[:] = False
        self.live = [None] * self.b
        self._finished = []


def _write_slot(cache, cache1, slot: int, s_max: int):
    """Copy a 1-row prefill cache into slot ``slot`` of the engine cache,
    padding KV sequence dims up to s_max."""

    def merge(dst, src):
        if isinstance(dst, dict):
            return {k: merge(dst[k], src[k]) for k in dst}
        if isinstance(dst, list):
            return [merge(a, b) for a, b in zip(dst, src)]
        # dst (count, B, ...), src (count, 1, ...)
        if dst.ndim >= 3 and src.shape[1] == 1:
            row = src[:, 0]
            target = dst.shape[:1] + dst.shape[2:]  # slot slice shape
            if row.shape != target:
                # KV ring buffers: prefill wrote fewer sequence rows; pad
                # the seq axis (-2) up to the engine's s_max
                pad = [(0, 0)] * row.ndim
                pad[-2] = (0, target[-2] - row.shape[-2])
                row = jnp.pad(row, pad)
            return dst.at[:, slot].set(row.astype(dst.dtype))
        return dst

    return merge(cache, cache1)


def _write_ragged(cache, packed, slots: list[int], lay, s_max: int):
    """Unpack a packed ragged-prefill cache into the engine slots.

    Every KV leaf of ``packed`` is (count, 1, Hkv, t_pad, D) with rows in
    packed order; the move into (count, B, Hkv, s_max, D) slot rows is ONE
    masked ``ragged_rows`` IndexPlan gather per leaf — sequence j's rows
    ``[indptr[j], indptr[j+1])`` land at slot rows ``[0, len_j)``, the -1
    sentinels past each length zero-fill the ring tail."""
    n = len(slots)
    s_eff = min(s_max, lay.t_pad)
    unp = lay.unpack_index(s_eff)  # (n, s_eff), -1 past each length
    slots_arr = np.asarray(slots, np.int32)

    def merge(dst, src):
        if isinstance(dst, dict):
            return {k: merge(dst[k], src[k]) for k in dst}
        if isinstance(dst, list):
            return [merge(a, b) for a, b in zip(dst, src)]
        count, _, hkv, t_pad, d = src.shape
        flat = src.reshape(count * hkv * t_pad, d)
        # packed row of (layer c, head h, token t) is (c*hkv + h)*t_pad + t
        base = (np.arange(count * hkv, dtype=np.int64) * t_pad).reshape(
            count, 1, hkv, 1
        )
        u4 = unp[None, :, None, :]  # (1, n, 1, s_eff)
        idx = np.where(u4 >= 0, base + u4, -1).astype(np.int32)
        plan = ip.plan_index_op(
            flat.shape, flat.dtype, idx.size, "ragged_rows", masked=True
        )
        rows = ops.apply_index_plan(flat, jnp.asarray(idx.reshape(-1)), plan)
        rows = rows.reshape(count, n, hkv, s_eff, d)
        return dst.at[:, slots_arr, :, :s_eff].set(rows.astype(dst.dtype))

    return merge(cache, packed)
