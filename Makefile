# One-command verify recipes (CI + local).  .github/workflows/ci.yml runs
# exactly these targets, so CI and local invocations stay identical.
#
#   make test            docs-check + tier-1 suite (the ROADMAP verify command)
#   make docs-check      public-API docstring lint (tools/check_docstrings.py)
#   make test-interpret  kernel/engine suites with every op forced through
#                        the Pallas interpreter (REPRO_PALLAS_INTERPRET=1)
#   make bench           benchmark harness; writes BENCH_rearrange.json
#                        (+ BENCH_stencil.json / BENCH_moe.json / BENCH_dist.json)
#   make bench-smoke     the same harness on tiny deterministic shapes
#                        (no JSON written — committed numbers stay intact)
#   make bench-check     benchmark-regression gate (tools/check_bench.py):
#                        structure + measured-path ratios of the committed
#                        BENCH_*.json, plus a fresh smoke replay
#   make bench-moe       MoE dispatch suite only; writes BENCH_moe.json
#   make bench-dist      mesh-aware suite only (8 forced host devices in a
#                        subprocess); writes BENCH_dist.json
#   make test-dist       distributed plan-engine suite directly on 8 forced
#                        host devices (the tier-1 run covers the same thing
#                        through a subprocess launcher test)
#   make test-train      gradient-correctness tier (flash backward vs the
#                        naive oracle, grad accumulation, blockwise-parallel
#                        blocks vs monolithic)
#   make lint            byte-compile + import sanity (no external linters
#                        are installed in the container) + fails if any
#                        __pycache__/.pyc path is git-tracked
#
# `test` deliberately does NOT set REPRO_PALLAS_INTERPRET globally: model
# smoke tests validate the default dispatch (jnp oracle on CPU), and the
# kernel suites opt into interpret mode per-test via the pallas_interpret
# fixture.  `test-interpret` covers the force-everything configuration on
# the suites designed for it.

PYTHONPATH := src

.PHONY: test test-interpret test-dist test-serve test-train bench bench-smoke \
	bench-check bench-moe bench-dist bench-serve bench-train lint check \
	docs-check

docs-check:
	python tools/check_docstrings.py

test: docs-check
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

test-interpret:
	PYTHONPATH=$(PYTHONPATH) REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q \
		tests/test_kernels.py tests/test_plan_engine.py tests/test_substrate.py \
		tests/test_properties.py tests/test_stencil_engine.py

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --smoke

bench-check:
	PYTHONPATH=$(PYTHONPATH) python tools/check_bench.py --out bench-check.json

bench-moe:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --only moe_dispatch --json ''

bench-dist:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --only dist --json ''

bench-serve:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --only serve --json ''

test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 REPRO_DIST_CHILD=1 \
		PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q tests/test_dist_plan.py

test-serve:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q tests/test_serve_engine.py

test-train:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q tests/test_train_engine.py

bench-train:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --only train --json ''

lint:
	python -m compileall -q src tests benchmarks examples
	PYTHONPATH=$(PYTHONPATH) python -c "import repro.core.rearrange, repro.core.plan, repro.core.tune, repro.kernels.ops, benchmarks.run, repro.tune"
	@tracked="$$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$$' || true)"; \
	if [ -n "$$tracked" ]; then \
		echo "lint: git-tracked bytecode (commit .gitignore'd files?):"; \
		echo "$$tracked"; exit 1; \
	fi

check: lint test
