"""Beyond-paper: MoE dispatch as the index-set rearrangement (DESIGN §4).

Compares the gather-kernel ('sort') dispatch against the one-hot-einsum
('dense') dispatch — same semantics, different data-movement strategy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro import configs
from repro.models import moe


def run() -> list[str]:
    cfg = configs.get_config("deepseek-moe-16b-smoke").with_(d_model=512)
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(key, cfg)
    x = jax.random.normal(key, (8, 512, cfg.d_model), jnp.float32).astype(cfg.np_dtype)
    t_tokens = 8 * 512
    # bytes: tokens gathered in + expert io + gathered back (rough lower bound)
    nbytes = 4 * t_tokens * cfg.d_model * 2 * cfg.moe.top_k
    out = []
    for mode in ("dense", "sort"):
        cfg_m = cfg.with_(moe=cfg.moe.__class__(**{**cfg.moe.__dict__, "dispatch": mode}))
        fn = jax.jit(lambda a, c=cfg_m: moe.moe_apply(p, c, a)[0])
        t = time_fn(fn, x)
        out.append(row(f"moe_dispatch_{mode}", t, nbytes))
    return out
