"""The paper's primary contribution: a composable data-rearrangement
library — layout algebra, movement planner, rearrange API, stencil API,
and the mesh-level distributed planner on top of them.

Public surface::

    from repro.core import rearrange, stencil, layout, plan, dist_plan
    rearrange.permute / permute_order / reorder / interlace / deinterlace
    rearrange.split_heads / merge_heads / space_to_depth / ...
    stencil.Stencil / fd_laplacian / apply_functor / conv1d_depthwise
    dist_plan.shard_permute / shard_interlace / StencilProgram.shard
"""

from repro.core import dist_plan, layout, plan, rearrange, stencil  # noqa: F401
