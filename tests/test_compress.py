"""int8 ring-collective gradient compression: numerical validation on a
forced 8-device host mesh (subprocess keeps the main process single-dev)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_int8_ring_allreduce_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import ring_allreduce_int8, wire_bytes
import functools

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("data",))
rng = np.random.default_rng(0)
# per-device distinct values; replicated layout, each shard sees its own copy
vals = rng.standard_normal((8, 4096)).astype(np.float32)

from repro.launch.mesh import shard_map_compat
fn = shard_map_compat(
    functools.partial(ring_allreduce_int8, axis_name="data"),
    mesh, P("data"), P("data"))
x = jnp.asarray(vals.reshape(-1))  # (8*4096,) sharded over data -> each dev one row
out = np.asarray(fn(x)).reshape(8, 4096)
want = vals.mean(axis=0)
# every device must hold (approximately) the mean; int8 -> ~1% error
for d in range(8):
    err = np.abs(out[d] - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.05, (d, err)
# wire accounting sanity
wb = wire_bytes(1_000_000, 8)
assert 3.5 < wb["ratio"] <= 4.0
print("RING_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "RING_OK" in r.stdout, r.stderr[-3000:]
