"""Llama-3.2-Vision-90B [hf: meta-llama/Llama-3.2-90B-Vision] — decoder
backbone with cross-attention image layers every 5th block (20 of 100).

Modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches, d_model); the backbone's
cross-attn layers consume them.  FSDP on: 90B params."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=False,
    fsdp=True,
    unit=("attn", "attn", "attn", "attn", "xattn"),
    n_frontend_tokens=1600,  # stub: precomputed vision patches
    source="hf:meta-llama/Llama-3.2-90B-Vision (unverified tier)",
)
