"""Fused flash attention (Pallas TPU) — hillclimb #1 in EXPERIMENTS §Perf.

Why this kernel exists: the pure-JAX chunked attention in
``models.attention`` is *algorithmically* flash (online softmax, O(S)
memory), but XLA materializes each (Sq, chunk) logits tile to HBM between
the two dots.  At qwen2 train_4k scale that is ~30 GB of HBM traffic per
layer per device — the memory roofline term is 5x the compute term.  The
fused kernel keeps the logits tile in VMEM: HBM traffic drops to the
Q/K/V/O streams, which is what the (8,128)-tiled DMA schedule below moves
and *nothing else*.

Layout: grid (BH, nQ, nK), K innermost with VMEM scratch (m, l, acc)
carried across K steps; out written on the last K step.  GQA is handled
by the q-index -> kv-index map (bh // group).  Causal masking is applied
per-tile from program ids; fully-masked tiles short-circuit via pl.when.

``dma_bytes()`` reports the kernel's exact HBM traffic from its grid x
BlockSpec schedule — the roofline accounting used for the §Perf 'after'
numbers (deterministic, not estimated).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import cdiv, force_interpret

NEG_INF = -1e30


def _flash_kernel(
    nk: int, bq: int, bk: int, causal: bool, skv: int,
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    needed = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(needed)
    def compute():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        valid = k_pos < skv
        if causal:
            valid = valid & (q_pos >= k_pos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        # zero OOB value rows: the final partial K tile reads padded HBM
        # rows whose contents are unspecified (0 * NaN would poison acc)
        v_rows = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        v_clean = jnp.where(v_rows < skv, v_ref[0], jnp.zeros((), v_ref.dtype))
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_clean.dtype), v_clean, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked online-softmax attention over decode-layout (B, H, S, D)
    tensors, GQA-aware (Hq a multiple of Hkv); out = softmax(qk^T/sqrt(d))v
    with optional causal masking."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = cdiv(sq, bq), cdiv(skv, bk)

    q3 = q.reshape(b * hq, sq, d)
    k3 = k.reshape(b * hkv, skv, d)
    v3 = v.reshape(b * hkv, skv, d)

    def kv_index(bh, iq, ik):
        return (bh // g, ik, 0)

    interpret = force_interpret() if interpret is None else interpret
    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk, bq, bk, causal, skv),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, hq, sq, d)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_attention_triangular(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal flash with a *triangular* grid: only the nq(nq+1)/2
    lower-triangle (iq, ik) tiles are visited, so K/V DMA traffic halves
    vs the rectangular grid.  The (iq, ik) coordinates per grid step come
    from scalar-prefetched index tables — the same constant-memory
    analogue the paper uses for reorder strides (§III-B).  Requires
    Sq == Skv (self-attention)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if sq != skv:
        raise ValueError("triangular grid needs Sq == Skv")
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if bq != bk:
        bq = bk = min(bq, bk)
    nq = cdiv(sq, bq)
    ntiles = nq * (nq + 1) // 2

    # lower-triangle walk, row-major: (0,0),(1,0),(1,1),(2,0)...
    iq_tab, ik_tab = [], []
    for i in range(nq):
        for j in range(i + 1):
            iq_tab.append(i)
            ik_tab.append(j)
    tables = jnp.array([iq_tab, ik_tab], jnp.int32)  # (2, ntiles)

    q3 = q.reshape(b * hq, sq, d)
    k3 = k.reshape(b * hkv, skv, d)
    v3 = v.reshape(b * hkv, skv, d)

    def kernel(tab_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        t = pl.program_id(1)
        iq = tab_ref[0, t]
        ik = tab_ref[1, t]

        @pl.when(ik == 0)
        def init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        qv = q_ref[0]
        kv = k_ref[0]
        s = jax.lax.dot_general(
            qv, kv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = (q_pos >= k_pos) & (k_pos < skv)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v_rows = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
        v_clean = jnp.where(v_rows < skv, v_ref[0], jnp.zeros((), v_ref.dtype))
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_clean.dtype), v_clean, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(ik == iq)  # last tile of this q row
        def finalize():
            o_ref[0] = (
                acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
            ).astype(o_ref.dtype)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hq, ntiles),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, t, tab: (bh, tab[0, t], 0)),
            pl.BlockSpec((1, bk, d), lambda bh, t, tab: (bh // g, tab[1, t], 0)),
            pl.BlockSpec((1, bk, d), lambda bh, t, tab: (bh // g, tab[1, t], 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, t, tab: (bh, tab[0, t], 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        interpret=interpret,
    )(tables, q3, k3, v3)
    return out.reshape(b, hq, sq, d)


def dma_bytes(
    b: int, hq: int, hkv: int, sq: int, skv: int, d: int, itemsize: int,
    *, block_q: int = 512, block_k: int = 512, causal: bool = True,
) -> int:
    """Exact HBM traffic of the kernel from its grid x BlockSpec schedule:
    Q loaded once per (iq, ik) visit, K/V once per visit, O once per iq.
    With causal skipping, ~half the (iq, ik) tiles load K/V only to be
    skipped — the Pallas pipeline still DMAs mapped blocks, so we count
    them (upper bound; a triangle-remapped index map would halve this)."""
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = cdiv(sq, bq), cdiv(skv, bk)
    q_bytes = b * hq * nq * nk * bq * d * itemsize
    kv_bytes = 2 * b * hq * nq * nk * bk * d * itemsize  # via the bh//g map
    o_bytes = b * hq * nq * bq * d * itemsize
    return q_bytes + kv_bytes + o_bytes
