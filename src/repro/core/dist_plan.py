"""The distributed plan engine: decompose -> reuse local plan -> cache
(DESIGN.md §10).

On a production mesh the scarce bandwidth is the interconnect, not HBM, so
sharded rearrangement is planned exactly like tiled rearrangement: a
:class:`DistPlan` decomposes any mesh-level movement into

    (optional collective) -> local cached plan -> (optional collective)

and memoizes the decision on ``(mesh_shape, in_spec, out_spec,
local_plan_key)``.  The *local* stage of every strategy is one of the three
existing per-device engines — ``core/plan.py`` (§3), ``core/stencil.py``
(§9), ``core/index_plan.py`` (§4) — run unchanged on each shard, so a
sharded op still lowers to the same single-``pallas_call`` kernels per
device; the planner's only new job is choosing what (if anything) crosses
the wire:

* ``local``       — the requested output sharding is the permuted input
                    sharding (or nothing is sharded): zero bytes on wire.
* ``all_to_all``  — axis-aligned redistribution: ONE tiled ``all_to_all``
                    moves ``(P-1)/P`` of the array, then the local plan
                    runs on the re-sharded shard.
* ``halo``        — stencil programs exchange ``sum(radius_i)`` edge rows
                    with mesh neighbors (one ``ppermute`` pair per k-block)
                    and run the fused temporal-blocking kernel per shard.
* ``ep``          — expert-parallel MoE: the blocked dispatch/combine
                    kernels sandwich a capacity-bucketed ``all_to_all``
                    pair (one per direction), keeping the gathered
                    intermediate out of HBM *and* off the wire.
* ``replicate``   — fallback for specs with no aligned collective:
                    ``all_gather``, run the full local plan, slice.  The
                    library never fails on an awkward spec; it loses the
                    wire-optimal path (same contract as the kernels).

Every plan carries the predicted bytes-on-wire of its strategy so callers
and ``benchmarks/bench_dist.py`` can compare strategies the same way the
per-device planners expose predicted HBM traffic.

``tuned=`` (DESIGN.md §11) ranks every *feasible* strategy decomposition
through the autotuner's cost model instead of taking the first feasible
one; all strategies are movement-only and bit-identical, so the swap
never changes results.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import tune
from repro.core.plan import ICI_GBPS_PER_LINK, plan_rearrange
from repro.kernels import ops
from repro.utils.roofline import movement_cost_s

# NOTE: the shard_map/ppermute shims live in repro.launch.mesh and are
# imported lazily inside the executors — the planner half of this module
# (everything above the executors) stays importable with no coupling
# beyond core/kernels, and no import cycle can form through launch.

Array = jax.Array

#: strategies a DistPlan can route to (DESIGN.md §10 cost table).
STRATEGIES = ("local", "all_to_all", "halo", "ep", "replicate", "noop")


# ---------------------------------------------------------------------------
# keys: meshes and PartitionSpecs as plain hashable data
# ---------------------------------------------------------------------------


def mesh_key(mesh) -> tuple[tuple[str, int], ...]:
    """Reduce a ``jax.sharding.Mesh`` to the hashable ``((name, size), ...)``
    tuple every planner caches on (plans are pure metadata — they never
    hold device objects)."""
    return tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names)


def spec_key(spec, ndim: int) -> tuple:
    """Normalize a PartitionSpec (or None) to a rank-``ndim`` tuple whose
    entries are ``None``, a mesh-axis name, or a tuple of names."""
    entries = tuple(spec) if spec is not None else ()
    if len(entries) > ndim:
        raise ValueError(f"spec {spec} longer than rank {ndim}")
    entries = entries + (None,) * (ndim - len(entries))
    out = []
    for e in entries:
        if e is None or isinstance(e, str):
            out.append(e)
        else:
            t = tuple(e)
            out.append(t[0] if len(t) == 1 else t)
    return tuple(out)


def sharded_axes(spec_t: tuple) -> dict[int, str]:
    """Map logical axis -> mesh-axis name for single-name entries.  Entries
    sharding one logical axis over multiple mesh axes raise (the distributed
    planner routes those to the ``replicate`` fallback before calling this).
    """
    out: dict[int, str] = {}
    for ax, e in enumerate(spec_t):
        if e is None:
            continue
        if not isinstance(e, str):
            raise ValueError(f"multi-axis sharding {e} has no aligned collective")
        out[ax] = e
    return out


def _axis_sizes(mesh_shape: tuple) -> dict[str, int]:
    return dict(mesh_shape)


def _replicas(mesh_shape: tuple, involved: int) -> int:
    """Replica groups a collective runs in: the mesh axes NOT carrying the
    op replicate it, so total wire traffic is the per-group cost times
    ``total_devices / involved`` (``involved`` = devices per comm group)."""
    total = 1
    for _, s in mesh_shape:
        total *= int(s)
    return max(total // max(involved, 1), 1)


# ---------------------------------------------------------------------------
# the plan object
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DistPlan:
    """Cached decomposition of one mesh-level movement.

    Mirrors :class:`repro.core.plan.RearrangePlan` one layer up the
    transport hierarchy: the strategy (collective choice), the mesh axis
    that carries the communication, the in/out shardings, the cache key of
    the *local* plan each shard reuses, and the predicted bytes-on-wire so
    callers and benchmarks can compare strategies.

    Example::

        plan = plan_dist_rearrange(mesh_key(mesh), spec_key(P("x"), 3),
                                   None, (8, 6, 128), jnp.float32, (1, 0, 2))
        print(plan.describe())
    """

    workload: str  # rearrange | interlace | stencil | moe
    strategy: str  # one of STRATEGIES
    mesh_shape: tuple[tuple[str, int], ...]
    axis: str | None  # mesh axis carrying the communication (None = no comm)
    in_spec: tuple
    out_spec: tuple
    local_key: tuple  # cache key of the per-shard local plan being reused
    detail: tuple  # strategy-specific geometry (see each planner)
    collectives: tuple[str, ...]  # primitive names, in execution order
    bytes_on_wire: int  # total interconnect traffic across the mesh
    bytes_local: int  # per-device HBM traffic of the local plan(s)
    wire_roofline_s: float  # bytes_on_wire / one ICI link

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        mesh = "x".join(f"{n}={s}" for n, s in self.mesh_shape)
        comm = ",".join(self.collectives) if self.collectives else "none"
        return (
            f"{self.workload}/{self.strategy}: mesh({mesh}) axis={self.axis} "
            f"{self.in_spec}->{self.out_spec} collectives=[{comm}] "
            f"{self.bytes_on_wire/1e6:.2f} MB on wire "
            f"(+{self.bytes_local/1e6:.2f} MB local HBM), "
            f"wire roofline {self.wire_roofline_s*1e6:.1f} us "
            f"@ {ICI_GBPS_PER_LINK} GB/s/link"
        )


def _mk(workload, strategy, mesh_shape, axis, in_spec, out_spec, local_key,
        detail, collectives, wire, local) -> DistPlan:
    return DistPlan(
        workload=workload,
        strategy=strategy,
        mesh_shape=mesh_shape,
        axis=axis,
        in_spec=in_spec,
        out_spec=out_spec,
        local_key=local_key,
        detail=detail,
        collectives=tuple(collectives),
        bytes_on_wire=int(wire),
        bytes_local=int(local),
        wire_roofline_s=wire / (ICI_GBPS_PER_LINK * 1e9),
    )


# ---------------------------------------------------------------------------
# workload 1: sharded rearrangement (permute / interlace)
# ---------------------------------------------------------------------------


def permuted_spec(in_spec: tuple, perm: Sequence[int]) -> tuple:
    """The output sharding a comm-free local permute produces: the input
    sharding carried along by the permutation (``out[j] = in[perm[j]]``)."""
    return tuple(in_spec[p] for p in perm)


def _build_rearrange(
    mesh_shape: tuple,
    in_spec: tuple,
    out_spec: tuple | None,
    shape: tuple[int, ...],
    dtype_name: str,
    perm: tuple[int, ...],
    strategy: str | None = None,
) -> DistPlan:
    """Decompose one sharded permute into collective + local plan.

    ``strategy`` forces one route (the tuner's hook — an infeasible
    forced strategy raises ``ValueError``); with ``None`` the planner
    keeps its preference order local > all_to_all > replicate, exactly
    the pre-tuner behavior.
    """
    sizes = _axis_sizes(mesh_shape)
    itemsize = jnp.dtype(dtype_name).itemsize
    n_elems = 1
    for s in shape:
        n_elems *= int(s)
    gbytes = n_elems * itemsize
    derived = permuted_spec(in_spec, perm)
    if out_spec is None:
        out_spec = derived

    def shard_div(spec_t):
        """Local shape under spec_t; None when some sharded dim is ragged.
        Multi-axis entries divide by the product of their axis sizes (they
        have no aligned all_to_all, but local execution is still local)."""
        local = list(shape)
        for ax, e in enumerate(spec_t):
            p = 1
            for name in (e,) if isinstance(e, str) else (e or ()):
                p *= sizes.get(name, 1)
            if local[ax] % p:
                return None
            local[ax] //= p
        return tuple(local)

    def local_plan_of(local_shape):
        lp = plan_rearrange(local_shape, dtype_name, perm)
        return (local_shape, dtype_name, perm), lp.bytes_moved

    in_local = shard_div(in_spec)

    def sig(spec_t):
        """Spec signature modulo size-1 mesh axes (which shard nothing)."""
        out = []
        for e in spec_t:
            if e is None:
                out.append(None)
            elif isinstance(e, str):
                out.append(e if sizes.get(e, 1) > 1 else None)
            else:
                t = tuple(n for n in e if sizes.get(n, 1) > 1)
                out.append(t[0] if len(t) == 1 else (t if t else None))
        return tuple(out)

    # --- sharding carried by the permutation: comm-free local execution ---
    # (covers fully-replicated arrays and size-1 mesh axes, where any
    # requested output sharding is a no-op and the permute is local)
    if in_local is not None and sig(out_spec) == sig(derived):
        if strategy in (None, "local"):
            key, lb = local_plan_of(in_local)
            return _mk("rearrange", "local", mesh_shape, None, in_spec, out_spec,
                       key, (), (), 0, lb)
    elif strategy == "local":
        raise ValueError("local strategy infeasible: output sharding differs")

    # --- axis-aligned redistribution: one tiled all_to_all, then local ---
    in_sh = None
    try:
        in_sh = sharded_axes(sig(in_spec))
        out_sh = sharded_axes(sig(out_spec))
    except ValueError:
        in_sh = None
    if (
        strategy in (None, "all_to_all")
        and in_sh is not None
        and len(in_sh) == 1
        and len(out_sh) == 1
    ):
        (a, m_in), = in_sh.items()
        (j, m_out), = out_sh.items()
        b = perm[j]  # logical input axis the output wants sharded
        p = sizes.get(m_in, 1)
        if (
            m_in == m_out
            and p > 1
            and b != a
            and shape[a] % p == 0
            and shape[b] % p == 0
        ):
            # after the exchange each shard holds (full a, b/P): split the
            # local block along b, concat received chunks along a
            resharded = list(shape)
            resharded[b] //= p
            key, lb = local_plan_of(tuple(resharded))
            wire = gbytes * (p - 1) // p * _replicas(mesh_shape, p)
            return _mk("rearrange", "all_to_all", mesh_shape, m_in, in_spec,
                       out_spec, key, (a, b, p), ("all_to_all",), wire, lb)
    if strategy == "all_to_all":
        raise ValueError("all_to_all strategy infeasible for these specs")

    # --- fallback: gather everything, run the full local plan, slice ---
    # within one dim the gathers must run minor-axis-first: the minor
    # all_gather makes each device's chunk contiguous before the major
    # all_gather concatenates chunks (major-first would interleave blocks)
    gather_axes = []
    for ax, e in enumerate(in_spec):
        names = (e,) if isinstance(e, str) else tuple(reversed(e or ()))
        prod = 1
        for name in names:
            prod *= sizes.get(name, 1)
        if shape[ax] % prod:
            raise ValueError(
                f"dim {ax} of {shape} not divisible by mesh axes "
                f"{names} (x{prod}) — cannot shard"
            )
        gather_axes.extend(
            (ax, name) for name in names if sizes.get(name, 1) > 1
        )
    slice_axes = []
    for j, e in enumerate(out_spec):
        for name in ((e,) if isinstance(e, str) else (e or ())):
            if sizes.get(name, 1) > 1:
                if shape[perm[j]] % sizes[name]:
                    raise ValueError(
                        f"out dim {j} ({shape[perm[j]]}) not divisible by mesh "
                        f"axis {name!r} ({sizes[name]}) — cannot shard"
                    )
                slice_axes.append((j, name))
    key, lb = local_plan_of(shape)
    # all_gather delivers (shards-1) remote shards to each group device,
    # repeated in every replica group over the uninvolved mesh axes
    wire = 0
    shards = 1
    for _, name in gather_axes:
        shards *= sizes[name]
    if shards > 1:
        wire = gbytes * (shards - 1) * _replicas(mesh_shape, shards)
    comm_axis = gather_axes[0][1] if gather_axes else (
        slice_axes[0][1] if slice_axes else None
    )
    return _mk("rearrange", "replicate", mesh_shape, comm_axis, in_spec,
               out_spec, key, (tuple(gather_axes), tuple(slice_axes)),
               ("all_gather",) * len(gather_axes), wire, lb)


@functools.lru_cache(maxsize=4096)
def _plan_rearrange_cached(
    mesh_shape: tuple,
    in_spec: tuple,
    out_spec: tuple | None,
    shape: tuple[int, ...],
    dtype_name: str,
    perm: tuple[int, ...],
) -> DistPlan:
    return _build_rearrange(mesh_shape, in_spec, out_spec, shape, dtype_name, perm)


def _dist_cost_s(plan: DistPlan) -> float:
    """Strategy score: local HBM traffic plus the wire term (bytes at one
    ICI link, one launch latency per collective)."""
    return movement_cost_s(
        plan.bytes_local,
        1,
        wire_bytes=plan.bytes_on_wire,
        collectives=len(plan.collectives),
    )


def _select_strategy(
    engine: str, key: str, plans: list[DistPlan], mode: str
) -> DistPlan:
    """Pick among feasible strategy decompositions by cost model.

    Strategies are proven bit-identical (the §10 test suite), so choice
    only moves bytes between wire and HBM.  There is no measured runner —
    a cached planner cannot re-materialize the caller's mesh — so the
    tuner's cost fallback does the ranking in every mode; the point of
    routing through :func:`repro.core.tune.select` is the shared tie-break
    contract (the planner's preferred strategy is first) and the recorded
    search space.
    """
    cands = [
        tune.Candidate(label=p.strategy, params=(("i", i),), cost_s=_dist_cost_s(p))
        for i, p in enumerate(plans)
    ]
    choice = tune.select(engine, key, cands, None, mode=mode)
    return plans[choice.param_dict()["i"]]


@functools.lru_cache(maxsize=4096)
def _plan_rearrange_tuned(
    mesh_shape: tuple,
    in_spec: tuple,
    out_spec: tuple | None,
    shape: tuple[int, ...],
    dtype_name: str,
    perm: tuple[int, ...],
    mode: str,
) -> DistPlan:
    base = _plan_rearrange_cached(
        mesh_shape, in_spec, out_spec, shape, dtype_name, perm
    )
    if base.strategy in ("local", "noop"):
        return base  # zero bytes on wire: nothing can beat it
    plans = [base]
    for strat in STRATEGIES:
        if strat in (base.strategy, "local", "halo", "ep", "noop"):
            continue
        try:
            plans.append(
                _build_rearrange(
                    mesh_shape, in_spec, out_spec, shape, dtype_name, perm, strat
                )
            )
        except ValueError:
            continue
    return _select_strategy(
        "dist-rearrange",
        f"mesh={mesh_shape}|{in_spec}->{out_spec}|shape={shape}"
        f"|dtype={dtype_name}|perm={perm}",
        plans,
        mode,
    )


def plan_dist_rearrange(
    mesh_shape: tuple,
    in_spec: tuple,
    out_spec: tuple | None,
    shape: Sequence[int],
    dtype,
    perm: Sequence[int],
    *,
    tuned: bool | None = None,
) -> DistPlan:
    """Plan (and cache) a sharded ``permute(x, perm)``.

    ``mesh_shape`` is :func:`mesh_key` data; ``in_spec``/``out_spec`` are
    :func:`spec_key` tuples (``out_spec=None`` requests the comm-free
    sharding, i.e. the input sharding carried along by the permutation).
    Repeated calls with equal arguments return the *identical* plan object.

    ``tuned=None`` resolves from ``REPRO_TUNE``; ``tuned=True`` ranks every
    feasible strategy decomposition through the autotuner's cost model
    (DESIGN.md §11) instead of taking the first feasible one.
    """
    perm_t = tuple(int(p) for p in perm)
    shape_t = tuple(int(s) for s in shape)
    if sorted(perm_t) != list(range(len(shape_t))):
        raise ValueError(f"bad perm {perm_t} for rank {len(shape_t)}")
    if tuned is None:
        tuned = tune.tune_default()
    key = (
        tuple(mesh_shape),
        spec_key(in_spec, len(shape_t)),
        None if out_spec is None else spec_key(out_spec, len(shape_t)),
        shape_t,
        jnp.dtype(dtype).name,
        perm_t,
    )
    if not tuned:
        return _plan_rearrange_cached(*key)
    return _plan_rearrange_tuned(*key, tune.resolve_mode())


@functools.lru_cache(maxsize=1024)
def _plan_interlace_cached(
    mesh_shape: tuple, spec: tuple, shape: tuple, dtype_name: str, n: int
) -> DistPlan:
    sizes = _axis_sizes(mesh_shape)
    itemsize = jnp.dtype(dtype_name).itemsize
    local = list(shape)
    for ax, e in enumerate(spec):
        names = (e,) if isinstance(e, str) else (e or ())
        p = 1
        for name in names:
            p *= sizes.get(name, 1)
        if local[ax] % p:
            raise ValueError(
                f"dim {ax} of {shape} not divisible by mesh axes {names} (x{p})"
            )
        local[ax] //= p
    n_local = 1
    for s in local:
        n_local *= int(s)
    # interlace is a position-wise expansion along the last axis, so ANY
    # sharding (even of the interlaced axis) commutes with it: shard s of
    # the output is exactly the interlace of shard s of each input.  Zero
    # bytes cross the wire, always.
    return _mk("interlace", "local", mesh_shape, None, spec, spec,
               (tuple(local), dtype_name, n), (n,), (), 0,
               2 * n * n_local * itemsize)


def plan_dist_interlace(
    mesh_shape: tuple, spec: tuple, shape: Sequence[int], dtype, n: int
) -> DistPlan:
    """Plan (and cache) a sharded ``interlace`` of ``n`` same-shape arrays.

    Interlace commutes with every sharding (it is position-wise along the
    last axis), so the plan is always comm-free — the point of routing it
    through the planner is the cache + the explicit 0-bytes-on-wire record.
    """
    if n < 1:
        raise ValueError(f"interlace wants n >= 1 arrays, got {n}")
    shape_t = tuple(int(s) for s in shape)
    return _plan_interlace_cached(
        tuple(mesh_shape), spec_key(spec, len(shape_t)), shape_t,
        jnp.dtype(dtype).name, int(n),
    )


# ---------------------------------------------------------------------------
# workload 2: halo-exchanged stencil programs
# ---------------------------------------------------------------------------


def _build_stencil(
    mesh_shape: tuple,
    axis: str,
    shape: tuple[int, int],
    dtype_name: str,
    stages: tuple,
    boundary: str,
    strategy: str | None = None,
) -> DistPlan:
    """Decompose one row-sharded stencil program into halo k-blocks (or a
    fallback strategy).

    ``strategy`` forces ``halo`` / ``replicate`` (the tuner's hook; an
    infeasible forced strategy raises ``ValueError``); ``None`` keeps the
    pre-tuner preference: halo whenever every stage radius fits one shard.
    """
    from repro.core import stencil as st

    sizes = _axis_sizes(mesh_shape)
    p = sizes.get(axis, 1)
    H, W = shape
    itemsize = jnp.dtype(dtype_name).itemsize
    in_spec = (axis, None)
    radii = tuple(st._stage_exec(d)[1] for d in stages)

    if H * W == 0:
        return _mk("stencil", "noop", mesh_shape, None, in_spec, in_spec,
                   (shape, dtype_name, stages, boundary), (), (), 0, 0)
    if p <= 1:
        lp = st.plan_stencil(shape, dtype_name, stages, boundary)
        return _mk("stencil", "local", mesh_shape, None, in_spec, in_spec,
                   (shape, dtype_name, stages, boundary), (), (), 0,
                   lp.bytes_moved)
    if H % p:
        raise ValueError(f"grid rows {H} not divisible by mesh axis {axis!r} ({p})")
    hl = H // p

    if max(radii, default=0) > hl or strategy == "replicate":
        if strategy == "halo":
            raise ValueError("halo strategy infeasible: a stage radius "
                             "reaches past the nearest neighbor")
        # gather the full grid, run the whole single-device plan, keep the
        # owned rows
        lp = st.plan_stencil(shape, dtype_name, stages, boundary)
        wire = H * W * itemsize * (p - 1) * _replicas(mesh_shape, p)
        return _mk("stencil", "replicate", mesh_shape, axis, in_spec, in_spec,
                   (shape, dtype_name, stages, boundary), (),
                   ("all_gather",), wire, lp.bytes_moved)

    # k-block partition: pack consecutive stages while the block's summed
    # radius stays within one shard (the ppermute pair only reaches the
    # nearest neighbor).  Each block costs ONE exchange; within a block the
    # whole stage run is the existing fused temporal-blocking kernel.
    blocks: list[tuple[int, int]] = []  # (n_stages, block_radius)
    cur_n = cur_r = 0
    for r in radii:
        if cur_n and cur_r + r > hl:
            blocks.append((cur_n, cur_r))
            cur_n = cur_r = 0
        cur_n += 1
        cur_r += r
    blocks.append((cur_n, cur_r))

    # local-plan reuse: each block lowers through the §9 stencil planner on
    # the halo-extended shard (periodic geometry resolves through the
    # clamped specs because the wrap rows are physically resident)
    geo_boundary = "zero" if boundary == "periodic" else boundary
    bytes_local = 0
    off = 0
    for n_b, r_b in blocks:
        block_stages = stages[off : off + n_b]
        off += n_b
        lp = st.plan_stencil((hl + 2 * r_b, W), dtype_name, block_stages,
                             geo_boundary)
        bytes_local += lp.bytes_moved
    wire = sum(
        2 * r_b * W * itemsize * p for _, r_b in blocks
    ) * _replicas(mesh_shape, p)
    collectives = tuple(
        c for _, r_b in blocks for c in (("ppermute", "ppermute") if r_b else ())
    )
    return _mk("stencil", "halo", mesh_shape, axis, in_spec, in_spec,
               ((hl, W), dtype_name, stages, boundary), tuple(blocks),
               collectives, wire, bytes_local)


@functools.lru_cache(maxsize=1024)
def _plan_stencil_cached(
    mesh_shape: tuple,
    axis: str,
    shape: tuple[int, int],
    dtype_name: str,
    stages: tuple,
    boundary: str,
) -> DistPlan:
    return _build_stencil(mesh_shape, axis, shape, dtype_name, stages, boundary)


@functools.lru_cache(maxsize=1024)
def _plan_stencil_tuned(
    mesh_shape: tuple,
    axis: str,
    shape: tuple[int, int],
    dtype_name: str,
    stages: tuple,
    boundary: str,
    mode: str,
) -> DistPlan:
    base = _plan_stencil_cached(mesh_shape, axis, shape, dtype_name, stages, boundary)
    if base.strategy != "halo":
        return base  # local/noop have no wire; replicate means halo is infeasible
    plans = [base]
    try:
        plans.append(
            _build_stencil(
                mesh_shape, axis, shape, dtype_name, stages, boundary, "replicate"
            )
        )
    except ValueError:
        pass
    return _select_strategy(
        "dist-stencil",
        f"mesh={mesh_shape}|axis={axis}|shape={shape}|dtype={dtype_name}"
        f"|b={boundary}|n_stages={len(stages)}",
        plans,
        mode,
    )


def plan_dist_stencil(
    mesh_shape: tuple,
    axis: str,
    shape: Sequence[int],
    dtype,
    stages: tuple,
    boundary: str = "zero",
    *,
    tuned: bool | None = None,
) -> DistPlan:
    """Plan (and cache) a stencil *program* on a row-sharded grid.

    ``stages`` are the :class:`repro.core.stencil.StencilProgram` stage
    descriptors; ``axis`` the mesh axis the rows are sharded over.  The plan
    partitions the program into k-blocks of consecutive stages whose summed
    radius fits one shard; each block costs one ``ppermute`` pair (send the
    top/bottom edge rows to the two neighbors) and runs as ONE fused local
    kernel per shard (§9 temporal blocking on the halo-extended shard).

    ``tuned=None`` resolves from ``REPRO_TUNE``; ``tuned=True`` ranks the
    halo decomposition against the replicate fallback through the
    autotuner's cost model (DESIGN.md §11).
    """
    shape_t = tuple(int(s) for s in shape)
    if len(shape_t) != 2:
        raise ValueError(f"stencil plans want 2-D shapes, got {shape_t}")
    if tuned is None:
        tuned = tune.tune_default()
    key = (
        tuple(mesh_shape), str(axis), shape_t, jnp.dtype(dtype).name,
        tuple(stages), str(boundary),
    )
    if not tuned:
        return _plan_stencil_cached(*key)
    return _plan_stencil_tuned(*key, tune.resolve_mode())


# ---------------------------------------------------------------------------
# workload 3: expert-parallel MoE dispatch
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1024)
def _plan_moe_cached(
    mesh_shape: tuple,
    axis: str,
    t_global: int,
    d_model: int,
    n_experts: int,
    capacity: int,
    top_k: int,
    dtype_name: str,
) -> DistPlan:
    from repro.core.index_plan import plan_index_op

    sizes = _axis_sizes(mesh_shape)
    p = sizes.get(axis, 1)
    itemsize = jnp.dtype(dtype_name).itemsize
    in_spec = (axis, None)
    if t_global % p:
        raise ValueError(f"tokens {t_global} not divisible by mesh axis {axis!r} ({p})")
    if n_experts % p:
        raise ValueError(
            f"experts {n_experts} not divisible by mesh axis {axis!r} ({p})"
        )
    tl = t_global // p
    el = n_experts // p
    slots = n_experts * capacity  # per source device
    # local plans being reused: the §4 blocked dispatch gather and the fused
    # gather+combine — identical kernels to the single-device moe_sort
    disp = plan_index_op((tl, d_model), dtype_name, slots, "gather", masked=True)
    comb = plan_index_op((slots, d_model), dtype_name, tl, "gather_combine",
                         masked=True, top_k=top_k)
    if p <= 1:
        return _mk("moe", "local", mesh_shape, None, in_spec, in_spec,
                   (disp.kernel, comb.kernel, slots, tl), (), (), 0,
                   disp.bytes_moved + comb.bytes_moved)
    # each direction moves the (P-1)/P remote fraction of every device's
    # (E*cap, D) slot block — in every replica group over uninvolved mesh
    # axes; the gathered intermediate itself never round-trips HBM (it is
    # produced by / consumed into the fused kernels)
    wire_dir = (
        p * slots * d_model * itemsize * (p - 1) // p
        * _replicas(mesh_shape, p)
    )
    return _mk("moe", "ep", mesh_shape, axis, in_spec, in_spec,
               (disp.kernel, comb.kernel, slots, tl),
               (p, el, capacity, top_k),
               ("all_to_all", "all_to_all"), 2 * wire_dir,
               disp.bytes_moved + comb.bytes_moved)


def plan_dist_moe(
    mesh_shape: tuple,
    axis: str,
    t_global: int,
    d_model: int,
    n_experts: int,
    capacity: int,
    top_k: int,
    dtype,
) -> DistPlan:
    """Plan (and cache) expert-parallel MoE dispatch+combine.

    ``capacity`` is per (source shard, expert) — the capacity bucketing that
    makes the exchanged slot blocks fixed-size so ONE tiled ``all_to_all``
    per direction suffices.  The local stages reuse the §4 IndexPlan
    kernels unchanged (blocked masked gather out, fused combine back).
    """
    return _plan_moe_cached(
        tuple(mesh_shape), str(axis), int(t_global), int(d_model),
        int(n_experts), int(capacity), int(top_k), jnp.dtype(dtype).name,
    )


# ---------------------------------------------------------------------------
# executors (the shard_map wrappers around the local engines)
# ---------------------------------------------------------------------------


def _pspec(spec_t: tuple) -> P:
    return P(*spec_t)


def shard_permute(
    x: Array,
    perm: Sequence[int],
    *,
    mesh,
    in_spec,
    out_spec=None,
    tuned: bool | None = None,
) -> Array:
    """Sharded N-D permute through the distributed plan engine.

    ``x`` is (or will be treated as) sharded per ``in_spec`` on ``mesh``.
    With ``out_spec=None`` the output keeps the input sharding carried along
    by the permutation — zero communication.  Requesting a different
    ``out_spec`` makes the planner insert the minimal axis-aligned
    ``all_to_all`` (or the ``replicate`` fallback) before the local plan.

    Example::

        y = shard_permute(x, (1, 0, 2), mesh=mesh, in_spec=P("b"))
        z = shard_permute(x, (1, 0, 2), mesh=mesh, in_spec=P("b"),
                          out_spec=P(None, None, "b"))   # one all_to_all
    """
    from repro.launch.mesh import shard_map_compat

    perm = tuple(int(p) for p in perm)
    plan = plan_dist_rearrange(
        mesh_key(mesh), spec_key(in_spec, x.ndim),
        None if out_spec is None else spec_key(out_spec, x.ndim),
        x.shape, x.dtype, perm, tuned=tuned,
    )
    if plan.strategy == "local":
        f = lambda xl: ops.permute(xl, perm)  # noqa: E731
    elif plan.strategy == "all_to_all":
        a, b, _p = plan.detail

        def f(xl):
            xl = jax.lax.all_to_all(
                xl, plan.axis, split_axis=b, concat_axis=a, tiled=True
            )
            return ops.permute(xl, perm)
    else:  # replicate
        gather_axes, slice_axes = plan.detail

        def f(xl):
            for ax, name in gather_axes:
                xl = jax.lax.all_gather(xl, name, axis=ax, tiled=True)
            y = ops.permute(xl, perm)
            for j, name in slice_axes:
                n_loc = y.shape[j] // dict(plan.mesh_shape)[name]
                start = jax.lax.axis_index(name) * n_loc
                y = jax.lax.dynamic_slice_in_dim(y, start, n_loc, axis=j)
            return y

    return shard_map_compat(
        f, mesh, in_specs=(_pspec(plan.in_spec),), out_specs=_pspec(plan.out_spec)
    )(x)


def shard_interlace(arrays: Sequence[Array], *, mesh, spec) -> Array:
    """Sharded interlace: ``n`` same-shape arrays interleaved along the last
    axis.  Always comm-free (see :func:`plan_dist_interlace`); each shard
    runs the existing single-kernel interlace and the output keeps ``spec``.
    """
    from repro.launch.mesh import shard_map_compat

    arrays = list(arrays)
    if not arrays:
        raise ValueError("interlace wants at least one array")
    plan = plan_dist_interlace(
        mesh_key(mesh), spec_key(spec, arrays[0].ndim), arrays[0].shape,
        arrays[0].dtype, len(arrays),
    )
    f = lambda *ls: ops.interlace(list(ls))  # noqa: E731
    return shard_map_compat(
        f, mesh,
        in_specs=tuple(_pspec(plan.in_spec) for _ in arrays),
        out_specs=_pspec(plan.out_spec),
    )(*arrays)


def shard_stencil(
    program,
    x: Array,
    *,
    mesh,
    axis: str,
    boundary: str = "zero",
    tuned: bool | None = None,
) -> Array:
    """Run a :class:`repro.core.stencil.StencilProgram` on a row-sharded
    2-D grid with halo exchange (DESIGN.md §10).

    Per k-block of the plan: one ``ppermute`` pair swaps ``block_radius``
    edge rows with the two mesh neighbors, the halo-extended shard runs the
    existing fused §9 kernel (global-row window semantics keep the four
    boundary modes exact at the true grid edges), and the owned rows are
    kept.  Bit-identical to ``program(x, boundary=...)`` on one device.
    """
    from repro.core import stencil as st
    from repro.launch.mesh import ring_perm, shard_map_compat

    if x.ndim != 2:
        raise ValueError(f"stencil programs want 2-D grids, got {x.shape}")
    plan = plan_dist_stencil(
        mesh_key(mesh), axis, x.shape, x.dtype, program.stages, boundary,
        tuned=tuned,
    )
    if plan.strategy == "noop":
        return x
    if plan.strategy == "local":
        return program(x, boundary=boundary)
    H, W = x.shape
    p = dict(plan.mesh_shape)[axis]
    hl = H // p
    stages_exec = tuple(st._stage_exec(d) for d in program.stages)

    if plan.strategy == "replicate":
        def f(xl):
            xg = jax.lax.all_gather(xl, axis, axis=0, tiled=True)
            y = ops.stencil_program(xg, stages_exec, boundary=boundary)
            start = jax.lax.axis_index(axis) * hl
            return jax.lax.dynamic_slice_in_dim(y, start, hl, axis=0)
    else:  # halo
        blocks = plan.detail
        perm_dn = ring_perm(p)  # i -> i+1: my bottom rows become their top halo
        perm_up = ring_perm(p, reverse=True)  # i -> i-1: top rows go up

        def f(xl):
            row0 = jax.lax.axis_index(axis).astype(jnp.int32) * hl
            off = 0
            for n_b, r_b in blocks:
                block = stages_exec[off : off + n_b]
                off += n_b
                if r_b:
                    top_halo = jax.lax.ppermute(xl[-r_b:], axis, perm_dn)
                    bot_halo = jax.lax.ppermute(xl[:r_b], axis, perm_up)
                    ext = jnp.concatenate([top_halo, xl, bot_halo], axis=0)
                else:
                    ext = xl
                y = ops.stencil_program(
                    ext, block, boundary=boundary,
                    window=(row0 - r_b, H),
                )
                xl = jax.lax.slice_in_dim(y, r_b, r_b + hl, axis=0) if r_b else y
            return xl

    return shard_map_compat(
        f, mesh, in_specs=(_pspec(plan.in_spec),), out_specs=_pspec(plan.out_spec)
    )(x)


def dist_plan_cache_info() -> dict:
    """Expose the per-workload plan-memo stats (tests / benchmarks)."""
    return {
        "rearrange": _plan_rearrange_cached.cache_info(),
        "rearrange_tuned": _plan_rearrange_tuned.cache_info(),
        "interlace": _plan_interlace_cached.cache_info(),
        "stencil": _plan_stencil_cached.cache_info(),
        "stencil_tuned": _plan_stencil_tuned.cache_info(),
        "moe": _plan_moe_cached.cache_info(),
    }
