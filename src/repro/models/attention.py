"""Attention: GQA flash (chunked online-softmax), block-local/SWA, cross,
and single-token decode — all pure JAX, GSPMD-shardable.

Layout engineering is where the paper's library plugs in (DESIGN.md §4):
head split/merge are §III-B permutes, the KV-cache prefill->decode layout
swap is `rearrange.kv_cache_to_decode_layout`, fused-QKV splitting is a
§III-C de-interlace.

Every head split/merge below goes through the plan engine (core/plan.py):
the (B, S, H, D)-swap family collapses to ONE batched 2-D transpose kernel
with D-deep vector elements per call — the projection reshape is folded
into the plan's canonical shape, so the hot per-layer permutes never
materialize a reshape intermediate (DESIGN.md §3-§4).

Shapes: q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D); GQA groups G = Hq // Hkv.
Softmax statistics are fp32 regardless of io dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rearrange as rr
from repro.models import common
from repro.utils.scanutil import maybe_scan

Array = jax.Array

NEG_INF = -1e30


def _use_flash_kernel() -> bool:
    import os

    if os.environ.get("REPRO_FLASH_KERNEL", "") == "1":
        return True
    if os.environ.get("REPRO_FLASH_KERNEL", "") == "0":
        return False
    return jax.default_backend() == "tpu"


def _use_decode_kernel() -> bool:
    """Split-KV decode dispatch: ``REPRO_DECODE_KERNEL`` (1/0) overrides;
    default follows the library's Pallas contract (TPU, or any platform
    under ``REPRO_PALLAS_INTERPRET=1``) so CPU tests keep the jnp oracle
    unless they opt in."""
    import os

    v = os.environ.get("REPRO_DECODE_KERNEL", "")
    if v == "1":
        return True
    if v == "0":
        return False
    from repro.kernels import ops

    return ops.use_pallas()


def _group_q(q: Array, n_kv: int) -> Array:
    b, hq, s, d = q.shape
    return q.reshape(b, n_kv, hq // n_kv, s, d)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    chunk: int = 512,
    q_offset: int = 0,
) -> Array:
    """Chunked online-softmax attention (never materializes Sq x Skv).

    ``q_offset``: absolute position of q[.., 0, :] relative to k (for
    prefill continuation / decode with cache).
    """
    b, hkv, skv, d = k.shape
    import os

    if os.environ.get("REPRO_ATTN_IDENTITY", "0") == "1":
        # analysis-only: excise attention math so the marginal-unit diff
        # isolates non-attention traffic; the fused kernel's DMA bytes are
        # then added from kernels.flash.dma_bytes (EXPERIMENTS §Perf).
        return q
    if _use_flash_kernel():
        # TPU fast path: the fused Pallas kernel (kernels/flash.py) keeps
        # the logits tile in VMEM — §Perf hillclimb #1.
        from repro.kernels import flash as flash_k

        return flash_k.flash_attention(
            q * (d ** -0.5), k, v, causal=causal, q_offset=q_offset,
            block_q=min(512, q.shape[2]), block_k=min(512, skv),
            interpret=jax.default_backend() != "tpu",
        )
    qg = _group_q(q, hkv)  # (B, Hkv, G, Sq, D)
    sq = qg.shape[3]
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    # pad KV to a chunk multiple so dynamic_slice never clamps (clamped
    # slices would double-count trailing keys); padded keys are masked.
    if n_chunks * chunk != skv:
        pad = n_chunks * chunk - skv
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    scale = d ** -0.5

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, i):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=2)
        s_log = common.feinsum("bhgqd,bhkd->bhgqk", qg, kc) * scale
        k_pos = i * chunk + jnp.arange(chunk)
        valid = k_pos < skv
        if causal:
            valid = (q_pos[:, None] >= k_pos[None, :]) & valid[None, :]
            s_log = jnp.where(valid, s_log, NEG_INF)
        else:
            s_log = jnp.where(valid[None, :], s_log, NEG_INF)
        m_new = jnp.maximum(m, s_log.max(axis=-1))
        p = jnp.exp(s_log - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + common.feinsum(
            "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vc
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full(qg.shape[:-1], NEG_INF, jnp.float32)
    l0 = jnp.zeros(qg.shape[:-1], jnp.float32)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    (m, l, acc), _ = maybe_scan(body, (m0, l0, acc0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(q.shape).astype(q.dtype)


def flash_attention_blockwise(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    chunk: int = 512,
    q_chunk: int = 1024,
    q_offset: int = 0,
    policy=None,
) -> Array:
    """Blockwise-parallel attention (DESIGN.md §13): the query axis is cut
    into ``q_chunk`` blocks, each computed under its own ``jax.checkpoint``
    so peak activation memory is one block, not the full sequence.

    Bit-identical to :func:`flash_attention` on the same inputs: every
    block calls the same chunked online-softmax (or Pallas kernel) with a
    static per-block ``q_offset``, and — when causal — the KV stream is
    truncated to the block's last needed ``chunk`` boundary.  Truncation is
    exact, not approximate: a fully-masked KV chunk contributes
    ``p = exp(NEG_INF - m) == 0.0`` (f32 underflow) and ``alpha == 1``, so
    the online-softmax state (m, l, acc) passes through such chunks
    unchanged.  ``policy`` is a resolved ``jax.checkpoint`` policy
    (``models.common.remat_policy``); ``None`` saves nothing (full
    recompute per block).
    """
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    cq = min(q_chunk, sq)
    ck = min(chunk, skv)

    def block(qc, kc, vc, off):
        return flash_attention(
            qc, kc, vc, causal=causal, chunk=chunk, q_offset=off
        )

    outs = []
    for lo in range(0, sq, cq):
        hi = min(sq, lo + cq)
        if causal:
            # KV rows past the block's last query are fully masked; keep
            # chunk boundaries aligned with the monolithic path so the
            # accumulation order is identical.
            kv_hi = min(skv, -(-(q_offset + hi) // ck) * ck)
        else:
            kv_hi = skv
        fn = jax.checkpoint(
            functools.partial(block, off=q_offset + lo), policy=policy
        )
        outs.append(fn(q[:, :, lo:hi], k[:, :, :kv_hi], v[:, :, :kv_hi]))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)


def local_attention(
    q: Array, k: Array, v: Array, *, window: int
) -> Array:
    """Block-local sliding-window attention, O(S * 2w): queries in block i
    attend to kv blocks {i-1, i} with a causal + window mask.  Sequences
    are padded up to a window multiple (padded keys sit at future
    positions, so causality masks them for every real query)."""
    b, hkv, s, d = k.shape
    w = window
    s_orig = s
    if s % w:
        pad = w - s % w
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s = s + pad
    qg = _group_q(q, hkv)
    g = qg.shape[2]
    nb = s // w
    scale = d ** -0.5

    qb = qg.reshape(b, hkv, g, nb, w, d)
    kb = k.reshape(b, hkv, nb, w, d)
    vb = v.reshape(b, hkv, nb, w, d)
    # previous kv block (zeros for block 0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], axis=2)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([kprev, kb], axis=3)  # (B, Hkv, nb, 2w, D)
    v2 = jnp.concatenate([vprev, vb], axis=3)

    logits = common.feinsum("bhgnqd,bhnkd->bhgnqk", qb, k2) * scale
    q_pos = jnp.arange(w)[:, None] + w  # position within the 2w strip
    k_pos = jnp.arange(2 * w)[None, :]
    mask = (q_pos >= k_pos) & (k_pos > q_pos - w)  # causal, within window
    first_block = jnp.arange(nb)[:, None, None] == 0
    valid = jnp.where(first_block, mask & (k_pos >= w), mask)
    logits = jnp.where(valid[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = common.feinsum("bhgnqk,bhnkd->bhgnqd", p.astype(v.dtype), v2)
    return out.reshape(q.shape)[:, :, :s_orig].astype(q.dtype)


def decode_attention(
    q1: Array, k: Array, v: Array, *,
    length: Array | None = None, engine: str | None = None,
) -> Array:
    """One-token decode: q1 (B, Hq, 1, D) vs cache (B, Hkv, S, D).

    ``length`` masks the cache tail — a scalar, or a (B,) per-slot vector
    so every slot of a continuous-batching engine attends over exactly its
    own valid rows (DESIGN.md §12).  ``engine`` picks the implementation:
    ``"splitkv"`` is the two-stage split-KV Pallas kernel
    (`kernels.flash.flash_decode`), ``"oneshot"`` the plain-reduction jnp
    path (GSPMD turns a sequence-sharded cache into partial-softmax +
    all-reduce automatically); ``None`` resolves from the dispatch contract
    (`_use_decode_kernel`).
    """
    b, hkv, s, d = k.shape
    if engine is None:
        engine = "splitkv" if _use_decode_kernel() else "oneshot"
    if engine == "splitkv":
        from repro.kernels import flash as flash_k

        lens = s if length is None else length
        lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32).reshape(-1), (b,))
        return flash_k.flash_decode(
            q1, k, v, lengths=lens,
            interpret=jax.default_backend() != "tpu",
        )
    qg = _group_q(q1, hkv)  # (B, Hkv, G, 1, D)
    logits = common.feinsum("bhgqd,bhkd->bhgqk", qg, k) * (d ** -0.5)
    if length is not None:
        pos = jnp.arange(s)
        lb = jnp.asarray(length)
        if lb.ndim == 0:
            mask = pos[None, None, None, None, :] < lb
        else:  # per-slot (B,) lengths
            mask = pos[None, None, None, None, :] < lb[:, None, None, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = common.feinsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(q1.shape).astype(q1.dtype)


def segment_attention(
    q: Array, k: Array, v: Array, *,
    seg_ids: Array, positions: Array, chunk: int = 512,
) -> Array:
    """Block-diagonal causal attention over a ``qo_indptr``-packed ragged
    batch (DESIGN.md §12): token i attends to token j iff they belong to
    the same segment and ``positions[i] >= positions[j]``.  ``seg_ids``
    (T,) carries the per-token sequence id with ``-1`` for padding rows
    (masked as keys everywhere); ``positions`` (T,) the within-sequence
    position.  Chunked online softmax like :func:`flash_attention` — the
    (T, T) mask is never materialized."""
    b, hkv, t, d = k.shape
    qg = _group_q(q, hkv)  # (B, Hkv, G, T, D)
    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    if n_chunks * chunk != t:
        pad = n_chunks * chunk - t
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        seg_k = jnp.pad(seg_ids, (0, pad), constant_values=-1)
        pos_k = jnp.pad(positions, (0, pad))
    else:
        seg_k, pos_k = seg_ids, positions
    scale = d ** -0.5

    def body(carry, i):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=2)
        sc = jax.lax.dynamic_slice_in_dim(seg_k, i * chunk, chunk, axis=0)
        pc = jax.lax.dynamic_slice_in_dim(pos_k, i * chunk, chunk, axis=0)
        s_log = common.feinsum("bhgqd,bhkd->bhgqk", qg, kc) * scale
        valid = (
            (seg_ids[:, None] == sc[None, :])
            & (sc[None, :] >= 0)
            & (positions[:, None] >= pc[None, :])
        )  # (T, chunk)
        s_log = jnp.where(valid, s_log, NEG_INF)
        m_new = jnp.maximum(m, s_log.max(axis=-1))
        p = jnp.exp(s_log - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + common.feinsum(
            "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vc
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full(qg.shape[:-1], NEG_INF, jnp.float32)
    l0 = jnp.zeros(qg.shape[:-1], jnp.float32)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    (m, l, acc), _ = maybe_scan(body, (m0, l0, acc0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(q.shape).astype(q.dtype)


def prefix_attention(
    q: Array, k: Array, v: Array, *, lengths: Array, chunk: int = 512,
) -> Array:
    """Chunked-prefill continuation attention (DESIGN.md §12): q (B, Hq, C,
    D) is a chunk of C new tokens per slot whose KV rows were just written
    into the ring at ``[lengths[b], lengths[b]+C)``; query row i of slot b
    attends to ring rows ``[0, lengths[b] + i + 1)`` — the already-valid
    prefix plus its own causal triangle.  Chunked online softmax over the
    ring axis."""
    b, hkv, s, d = k.shape
    qg = _group_q(q, hkv)  # (B, Hkv, G, C, D)
    c = qg.shape[3]
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    if n_chunks * chunk != s:
        pad = n_chunks * chunk - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    scale = d ** -0.5
    limit = lengths[:, None] + jnp.arange(c)[None, :] + 1  # (B, C)

    def body(carry, i):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=2)
        s_log = common.feinsum("bhgqd,bhkd->bhgqk", qg, kc) * scale
        k_pos = i * chunk + jnp.arange(chunk)
        valid = (k_pos[None, None, :] < limit[:, :, None]) & (
            k_pos[None, None, :] < s
        )  # (B, C, chunk)
        s_log = jnp.where(valid[:, None, None], s_log, NEG_INF)
        m_new = jnp.maximum(m, s_log.max(axis=-1))
        p = jnp.exp(s_log - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + common.feinsum(
            "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vc
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full(qg.shape[:-1], NEG_INF, jnp.float32)
    l0 = jnp.zeros(qg.shape[:-1], jnp.float32)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    (m, l, acc), _ = maybe_scan(body, (m0, l0, acc0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(q.shape).astype(q.dtype)


def cross_attention(q: Array, k: Array, v: Array) -> Array:
    """Full (non-causal) cross attention; encoder/image keys are short, so
    no chunking needed."""
    b, hkv, skv, d = k.shape
    qg = _group_q(q, hkv)
    logits = common.feinsum("bhgqd,bhkd->bhgqk", qg, k) * (d ** -0.5)
    p = jax.nn.softmax(logits, axis=-1)
    out = common.feinsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(q.shape).astype(q.dtype)


# ---------------------------------------------------------------------------
# parameterized attention layer (init + apply + decode)
# ---------------------------------------------------------------------------


def attn_init(key, cfg, *, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.head_dim_resolved
    kq, kk, ko = jax.random.split(key, 3)
    dt = cfg.np_dtype
    p = {
        "norm": common.norm_init(cfg.norm, d),
        "w_o": common.truncated_normal_init(ko, (cfg.n_heads * hd, d), 1.0, dt),
    }
    if cross:
        p["w_q"] = common.truncated_normal_init(kq, (d, cfg.n_heads * hd), 1.0, dt)
        p["w_kv"] = common.truncated_normal_init(kk, (d, 2 * cfg.n_kv_heads * hd), 1.0, dt)
    else:
        fused = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        p["w_qkv"] = common.truncated_normal_init(kq, (d, fused), 1.0, dt)
        if cfg.qkv_bias:
            p["b_qkv"] = jnp.zeros((fused,), dt)
    return p


def _shard_qkv(cfg, q: Array, k: Array, v: Array):
    """Attention sharding policy (set by the launcher via cfg.attn_shard):

    head  — Q heads on 'model' (Megatron); K/V heads too when divisible,
            replicated otherwise (GQA with few KV heads).
    seq   — Q sequence-sharded on 'model', K/V replicated: the layout
            fallback when head counts don't divide the model axis (e.g.
            28 heads on a 16-way axis).  Without this GSPMD contraction-
            shards head_dim and all-reduces the S^2 logits — catastrophic
            (EXPERIMENTS.md §Perf iteration 1).
    """
    from repro.sharding.partition import BATCH, constrain
    from jax.sharding import PartitionSpec as P

    if cfg.attn_shard == "head":
        q = constrain(q, P(BATCH, "model", None, None))
        kv_ax = "model" if cfg.n_kv_heads == cfg.n_heads else None
        k = constrain(k, P(BATCH, kv_ax, None, None))
        v = constrain(v, P(BATCH, kv_ax, None, None))
    elif cfg.attn_shard == "seq":
        q = constrain(q, P(BATCH, None, "model", None))
        k = constrain(k, P(BATCH, None, None, None))
        v = constrain(v, P(BATCH, None, None, None))
    return q, k, v


def _project_qkv(p: dict, cfg, x: Array) -> tuple[Array, Array, Array]:
    hd = cfg.head_dim_resolved
    qkv = x @ p["w_qkv"]
    if "b_qkv" in p:
        qkv = qkv + p["b_qkv"]
    q, k, v = rr.split_qkv(qkv, cfg.n_heads, cfg.n_kv_heads, hd)
    b, s, _ = x.shape
    # each split is one fused batched-transpose kernel (plan mode
    # 'transpose'), directly producing the (B, H, S, D) attention layout
    q = rr.split_heads(q, cfg.n_heads)        # (B, Hq, S, D)
    k = rr.split_heads(k, cfg.n_kv_heads)
    v = rr.split_heads(v, cfg.n_kv_heads)
    return _shard_qkv(cfg, q, k, v)


def attn_apply(
    p: dict,
    cfg,
    x: Array,
    *,
    kind: str = "full",  # full | swa | local | bidir
    positions: Array | None = None,
) -> Array:
    from repro.sharding.partition import constrain, replicated_spec, residual_spec

    h = common.apply_norm(cfg.norm, p["norm"], x)
    if getattr(cfg, "sp", False):
        h = constrain(h, replicated_spec(3))
    q, k, v = _project_qkv(p, cfg, x=h)
    s = x.shape[1]
    pos = jnp.arange(s) if positions is None else positions
    if cfg.use_rope:
        q = common.apply_rope(q, pos, cfg.rope_theta)
        k = common.apply_rope(k, pos, cfg.rope_theta)
    if kind in ("swa", "local") and s > cfg.window:
        o = local_attention(q, k, v, window=cfg.window)
    elif kind == "bidir":
        o = cross_attention(q, k, v)  # full bidirectional self-attn
    elif getattr(cfg, "blockwise", False):
        o = flash_attention_blockwise(
            q, k, v, causal=True, chunk=cfg.attn_chunk,
            q_chunk=cfg.blockwise_chunk,
            policy=common.remat_policy(cfg.remat_policy),
        )
    else:
        o = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = rr.merge_heads(o) @ p["w_o"]
    if getattr(cfg, "sp", False):
        out = constrain(out, residual_spec(cfg, 3))
    return x + out


def attn_prefill(
    p: dict, cfg, x: Array, *, kind: str = "full",
    positions: Array | None = None, seg_ids: Array | None = None,
) -> tuple[Array, dict]:
    """Like apply, but also returns the decode-layout KV cache.

    ``positions``/``seg_ids`` (both (T,)) switch the batch to the packed
    ragged layout: RoPE uses the within-sequence positions and attention is
    the block-diagonal :func:`segment_attention` (DESIGN.md §12)."""
    from repro.sharding.partition import constrain, replicated_spec, residual_spec

    h = common.apply_norm(cfg.norm, p["norm"], x)
    if getattr(cfg, "sp", False):
        h = constrain(h, replicated_spec(3))
    q, k, v = _project_qkv(p, cfg, x=h)
    s = x.shape[1]
    pos = jnp.arange(s) if positions is None else positions
    if cfg.use_rope:
        q = common.apply_rope(q, pos, cfg.rope_theta)
        k = common.apply_rope(k, pos, cfg.rope_theta)
    if seg_ids is not None:
        o = segment_attention(
            q, k, v, seg_ids=seg_ids, positions=pos, chunk=cfg.attn_chunk
        )
    elif kind in ("swa", "local") and s > cfg.window:
        o = local_attention(q, k, v, window=cfg.window)
    else:
        o = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    proj = rr.merge_heads(o) @ p["w_o"]
    if getattr(cfg, "sp", False):
        proj = constrain(proj, residual_spec(cfg, 3))
    out = x + proj
    cache = {"k": k, "v": v}  # already (B, Hkv, S, D) decode layout
    return out, cache


def attn_decode(
    p: dict, cfg, x1: Array, cache: dict, pos: Array, *, kind: str = "full"
) -> tuple[Array, dict]:
    """One-token decode. cache: k/v (B, Hkv, S_max, D) ring buffer; ``pos``
    is the absolute position — an int32 scalar (every slot at the same
    position, the seed path) or a (B,) per-slot vector (continuous
    batching, DESIGN.md §12): each slot writes its KV row at its OWN ring
    position and attends over exactly its own valid length.  For swa/local
    kinds S_max is the window and the slot is pos % window."""
    h = common.apply_norm(cfg.norm, p["norm"], x1)
    q, k, v = _project_qkv(p, cfg, x=h)
    pos = jnp.asarray(pos)
    if cfg.use_rope:
        # scalar -> (1,) broadcast; per-slot -> (B, 1, 1) so the rotation
        # angles broadcast against (B, H, 1, D/2)
        posv = pos[None] if pos.ndim == 0 else pos[:, None, None]
        q = common.apply_rope(q, posv, cfg.rope_theta)
        k = common.apply_rope(k, posv, cfg.rope_theta)
    s_max = cache["k"].shape[2]
    if pos.ndim == 0:
        slot = (pos % s_max) if kind in ("swa", "local") else pos
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)
    else:
        slotv = (
            (pos % s_max) if kind in ("swa", "local")
            else jnp.minimum(pos, s_max - 1)
        )
        bi = jnp.arange(x1.shape[0])
        kc = cache["k"].at[bi, :, slotv].set(k[:, :, 0])
        vc = cache["v"].at[bi, :, slotv].set(v[:, :, 0])
    length = jnp.minimum(pos + 1, s_max)  # scalar or (B,)
    o = decode_attention(q, kc, vc, length=length)
    out = x1 + rr.merge_heads(o) @ p["w_o"]
    return out, {"k": kc, "v": vc}


def attn_prefill_chunk(
    p: dict, cfg, x: Array, cache: dict, pos: Array, active: Array,
) -> tuple[Array, dict]:
    """Prefill one chunk of C prompt tokens per slot directly into the
    engine's ring caches (chunked prefill, DESIGN.md §12).

    ``x`` (B, C, D) hidden chunk; ``cache`` k/v (B, Hkv, S_max, D) rings;
    ``pos`` (B,) valid rows already in each slot's ring (the chunk's rows
    land at ``[pos, pos+C)``); ``active`` (B,) bool — inactive slots leave
    their cache untouched and their outputs are ignored.  Full-attention
    kinds only (the engine's scheduler gates this path)."""
    b, c, _ = x.shape
    h = common.apply_norm(cfg.norm, p["norm"], x)
    q, k, v = _project_qkv(p, cfg, x=h)  # (B, H, C, D)
    positions = pos[:, None] + jnp.arange(c)[None, :]  # (B, C)
    if cfg.use_rope:
        q = common.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = common.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    s_max = cache["k"].shape[2]
    rows = jnp.minimum(positions, s_max - 1)  # (B, C)
    bi = jnp.arange(b)[:, None]
    # scatter the chunk rows; advanced indexing puts (B, C) in front
    kc = cache["k"].at[bi, :, rows].set(jnp.swapaxes(k, 1, 2))
    vc = cache["v"].at[bi, :, rows].set(jnp.swapaxes(v, 1, 2))
    sel = active[:, None, None, None]
    kc = jnp.where(sel, kc, cache["k"])
    vc = jnp.where(sel, vc, cache["v"])
    o = prefix_attention(q, kc, vc, lengths=pos, chunk=cfg.attn_chunk)
    out = x + rr.merge_heads(o) @ p["w_o"]
    return out, {"k": kc, "v": vc}


def xattn_init(key, cfg) -> dict:
    return attn_init(key, cfg, cross=True)


def xattn_apply(p: dict, cfg, x: Array, kv_src: Array) -> Array:
    """Cross-attention block (decoder x: (B,S,D), kv_src: (B,Skv,D))."""
    hd = cfg.head_dim_resolved
    h = common.apply_norm(cfg.norm, p["norm"], x)
    q = rr.split_heads(h @ p["w_q"], cfg.n_heads)
    kv = kv_src @ p["w_kv"]
    k, v = jnp.split(kv, 2, axis=-1)
    k = rr.split_heads(k, cfg.n_kv_heads)
    v = rr.split_heads(v, cfg.n_kv_heads)
    o = cross_attention(q, k, v)
    return x + rr.merge_heads(o) @ p["w_o"]


def xattn_cache(p: dict, cfg, kv_src: Array) -> dict:
    """Precompute cross-attention K/V once (prefill) for decode reuse."""
    kv = kv_src @ p["w_kv"]
    k, v = jnp.split(kv, 2, axis=-1)
    return {
        "k": rr.split_heads(k, cfg.n_kv_heads),
        "v": rr.split_heads(v, cfg.n_kv_heads),
    }


def xattn_decode(p: dict, cfg, x1: Array, cache: dict) -> Array:
    h = common.apply_norm(cfg.norm, p["norm"], x1)
    q = rr.split_heads(h @ p["w_q"], cfg.n_heads)
    o = cross_attention(q, cache["k"], cache["v"])
    return x1 + rr.merge_heads(o) @ p["w_o"]
