"""HLO text analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` has no collective term, so we parse the optimized HLO
(post-SPMD, per-device program) and charge each collective its per-device
link traffic:

  all-gather          result_bytes           (each device receives ~N)
  reduce-scatter      operand_bytes          (each device sends ~N)
  all-reduce          2 * operand_bytes      (ring: reduce-scatter + all-gather)
  all-to-all          operand_bytes
  collective-permute  operand_bytes

'-start' variants are counted, '-done' ignored.  Shapes of operands are
resolved through a name->shape map built from the whole module.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes_bytes(text: str) -> int:
    """Total bytes of all array shapes in a type string (handles tuples)."""
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(text))


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective traffic (bytes) by op kind + totals."""
    # name -> result bytes
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        type_part = rhs.split(" ", 1)[0] if " " in rhs else rhs
        # result type = everything before the op name; just grab shapes
        # appearing before the first '(' (the instruction's result type)
        head = rhs.split("(", 1)[0]
        b = _first_shapes_bytes(head)
        if b:
            sizes[name.lstrip("%")] = b

    traffic = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opm = re.search(r"\b([a-z\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        base = op
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        else:
            continue
        if op.endswith("-done"):
            continue
        result_bytes = _first_shapes_bytes(rhs.split("(", 1)[0])
        # operand bytes: resolve %refs inside parens; fall back to inline types
        paren = rhs[rhs.index("(") :]
        operand_bytes = 0
        for ref in re.findall(r"%([\w.\-]+)", paren.split("),", 1)[0]):
            operand_bytes += sizes.get(ref, 0)
        if operand_bytes == 0:
            inner = paren.split("),", 1)[0]
            operand_bytes = _first_shapes_bytes(inner)
        if base == "all-gather":
            cost = result_bytes
        elif base == "all-reduce":
            cost = 2 * operand_bytes
        elif base == "reduce-scatter":
            cost = operand_bytes
        else:
            cost = operand_bytes
        traffic[base] += cost
        counts[base] += 1
    return {
        "bytes_by_kind": dict(traffic),
        "counts": dict(counts),
        "total_bytes": sum(traffic.values()),
    }
