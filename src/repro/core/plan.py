"""Rearrangement planner: collapse -> route -> cache (DESIGN.md §3).

The planner is the library's 'auto gridding' (paper §III-A: "gridding and
threading configuration is done automatically based on the data size") and
the single dispatch spine for every permute-shaped op:

1. **collapse** — merge contiguous input axes that stay adjacent under the
   permutation (:func:`repro.core.layout.coalesce`), so every reorder
   reduces to its minimal-rank canonical form;
2. **route** — pick the cheapest kernel for the canonical form:
   ``identity`` (pure reshape, no data movement), ``transpose`` (the
   adjacent-swap family -> batched 2-D transpose, `kernels/permute3d.py`),
   ``copy`` (fastest axis preserved -> blocked row gather), or ``reorder``
   (generic fallback, `kernels/reorder_nd.py`);
3. **cache** — plans are memoized on ``(shape, dtype, perm, grid_order)``
   so steady-state training/serving steps pay zero planning overhead
   (repeated calls return the *identical* plan object).

It also reports the predicted HBM traffic and roofline time so callers
(and the benchmarks) can compare achieved vs predicted movement.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp

from repro.core import layout
from repro.kernels.tiling import (
    plan_copy_tiles,
    plan_transpose_tiles,
    plan_transpose_vec_tiles,
)

# v5e per-chip hardware constants (also used by utils.roofline)
HBM_GBPS = 819.0
PEAK_BF16_TFLOPS = 197.0
ICI_GBPS_PER_LINK = 50.0


@dataclass(frozen=True)
class RearrangePlan:
    """Cached lowering decision for one permutation: the canonical
    (collapsed) form, the kernel route, the chosen tiles, and the predicted
    HBM traffic/roofline (DESIGN.md §3)."""

    mode: str  # identity | copy | transpose | reorder
    kernel: str  # noop | copy | transpose2d_batched[_vec] | reorder_nd
    canonical_shape: tuple[int, ...]
    canonical_perm: tuple[int, ...]
    out_shape: tuple[int, ...]  # full-rank output shape
    exec_shape: tuple[int, ...] | None  # (B, R, C, V) for transpose mode
    block_r: int
    block_c: int
    grid_order: str
    bytes_moved: int  # read + write
    roofline_s: float  # bytes / HBM bandwidth (one chip)

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        return (
            f"{self.mode}: shape={self.canonical_shape} perm={self.canonical_perm} "
            f"kernel={self.kernel} tiles=({self.block_r},{self.block_c}) "
            f"{self.bytes_moved/1e6:.2f} MB moved, "
            f"roofline {self.roofline_s*1e6:.1f} us @ {HBM_GBPS} GB/s"
        )


@functools.lru_cache(maxsize=4096)
def _plan_cached(
    shape: tuple[int, ...], dtype_name: str, perm: tuple[int, ...], grid_order: str
) -> RearrangePlan:
    canon = layout.canonicalize(shape, perm)
    itemsize = jnp.dtype(dtype_name).itemsize
    n_elems = 1
    for s in shape:
        n_elems *= int(s)
    out_shape = tuple(shape[p] for p in perm)
    bytes_moved = 2 * n_elems * itemsize  # read once + write once

    exec_shape = None
    factors = None if canon.mode == "identity" else layout.swap_factors(
        canon.shape, canon.perm
    )
    if n_elems == 0:
        # zero-size array: nothing to move, the output is an empty reshape
        return RearrangePlan(
            mode="identity",
            kernel="noop",
            canonical_shape=canon.shape,
            canonical_perm=canon.perm,
            out_shape=out_shape,
            exec_shape=None,
            block_r=1,
            block_c=1,
            grid_order=grid_order,
            bytes_moved=0,
            roofline_s=0.0,
        )
    if canon.mode == "identity" or canon.rows_axis is None:
        # no movement: the output is a metadata reshape of the input (a
        # caller that must materialize routes through the streaming copy
        # kernel, copy.py, with these tiles)
        mode, kernel = "identity", "noop"
        last = shape[-1] if shape else 1
        tp = plan_copy_tiles(max(n_elems // max(last, 1), 1), last, dtype_name)
        br, bc = tp.block_r, tp.block_c
    elif factors is not None:
        # adjacent-swap family: batched 2-D transpose plane, V-deep elements
        mode = "transpose"
        b, r, c, v = factors
        exec_shape = (b, r, c, v)
        if v > 1:
            kernel = "transpose2d_batched_vec"
            vp = plan_transpose_vec_tiles(r, c, v, dtype_name)
            br, bc = vp.block_r, vp.block_c
        else:
            kernel = "transpose2d_batched"
            tp = plan_transpose_tiles(r, c, dtype_name)
            br, bc = tp.block_r, tp.block_c
    elif canon.mode == "copy":
        # fastest axis preserved: blocked gather of contiguous rows
        mode, kernel = "copy", "reorder_nd"
        tp = plan_copy_tiles(
            canon.shape[canon.rows_axis], canon.shape[canon.cols_axis], dtype_name
        )
        br, bc = tp.block_r, tp.block_c
    else:
        # generic fallback: both fastest axes change, not a single swap
        mode, kernel = "reorder", "reorder_nd"
        tp = plan_transpose_tiles(
            canon.shape[canon.rows_axis], canon.shape[canon.cols_axis], dtype_name
        )
        br, bc = tp.block_r, tp.block_c

    return RearrangePlan(
        mode=mode,
        kernel=kernel,
        canonical_shape=canon.shape,
        canonical_perm=canon.perm,
        out_shape=out_shape,
        exec_shape=exec_shape,
        block_r=br,
        block_c=bc,
        grid_order=grid_order,
        bytes_moved=bytes_moved,
        roofline_s=bytes_moved / (HBM_GBPS * 1e9),
    )


def plan_rearrange(
    shape: Sequence[int],
    dtype,
    perm: Sequence[int],
    *,
    grid_order: str = "out",
) -> RearrangePlan:
    """Plan (and cache) the movement for ``transpose(x, perm)``."""
    perm_t = tuple(int(p) for p in perm)
    if sorted(perm_t) != list(range(len(shape))):
        raise ValueError(f"bad perm {perm_t} for rank {len(shape)}")
    if grid_order not in ("in", "out"):
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    return _plan_cached(
        tuple(int(s) for s in shape),
        jnp.dtype(dtype).name,
        perm_t,
        grid_order,
    )


def plan_cache_info():
    """Expose the memo stats (tests / benchmarks)."""
    return _plan_cached.cache_info()
