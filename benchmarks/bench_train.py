"""Beyond-paper: the blockwise-parallel training hot path (DESIGN §13).

Two row families at equal semantics:

* **kernel-phase roofline** — the flash forward and the recompute-based
  flash backward (dq + dk/dv pallas_calls) timed separately at the same
  attention shape, each against its own exact DMA byte count
  (``kernels.flash.dma_bytes`` / ``bwd_dma_bytes``), plus the chunked
  FFN's forward and backward — so ``BENCH_train.json`` carries the
  roofline utilization per training phase (fwd / bwd-attn / bwd-ffn), the
  same accounting the §11 autotuner's cost model uses for the bwd tile.
* **the train step** — ``make_train_step`` end to end (value_and_grad +
  AdamW) for the monolithic vs the blockwise-parallel model at a
  train_4k-proportioned (seq-dominant, memory-limited) shape, reporting
  tokens/s/device.  Both rows use the same algorithmic byte count, so the
  GB/s ratio in ``tools/check_bench.py`` is a pure time ratio (floor:
  blockwise >= 0.7x monolithic — the blockwise path exists to cut peak
  activation memory, and the gate asserts it does not *cost* throughput
  beyond a tolerance band).

Rows land in ``BENCH_train.json`` (see benchmarks/run.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, smoke, time_fn
from repro import configs
from repro.kernels import flash
from repro.models import mlp
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train import trainer


def _attn_phase_rows(out: list[str]) -> None:
    """Flash forward vs flash backward at one attention shape, each against
    its exact DMA byte count (phase-level roofline utilization)."""
    b, hq, hkv, s, d = (1, 4, 2, 128, 32) if smoke() else (2, 8, 2, 1024, 64)
    key = jax.random.PRNGKey(0)
    kq, kk, kv_, ko = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, hq, s, d), jnp.float32)
    k = jax.random.normal(kk, (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(kv_, (b, hkv, s, d), jnp.float32)
    do = jax.random.normal(ko, (b, hq, s, d), jnp.float32)
    interp = jax.default_backend() != "tpu"
    plan = flash.plan_flash_bwd(b, hq, hkv, s, s, d, jnp.float32)
    bq, bk = plan.block_q, plan.block_k
    out.append(f"# attn shapes b={b} hq={hq} hkv={hkv} s={s} d={d}")
    out.append(f"# flash bwd plan: {plan.describe()}")

    fwd = jax.jit(
        lambda a, c, w: flash.flash_attention(
            a, c, w, causal=True, block_q=bq, block_k=bk, interpret=interp
        )
    )
    t_fwd = time_fn(fwd, q, k, v)
    fwd_bytes = flash.dma_bytes(b, hq, hkv, s, s, d, 4, block_q=bq, block_k=bk)
    out.append(
        row("train_fwd_attn", t_fwd, fwd_bytes, "[flash fwd kernel]",
            phase="fwd", plan_mode="flash", measured="pallas",
            block_q=bq, block_k=bk)
    )

    # time the backward sweep alone: the fwd recompute is part of the bwd
    # kernels already; the (o, lse) residuals are produced once here
    o, lse = flash._flash_call(q, k, v, True, 0, bq, bk, interp)
    bwd = jax.jit(
        lambda a, c, w, g, oo, ll: flash.flash_attention_bwd(
            a, c, w, oo, ll, g, causal=True, block_q=bq, block_k=bk,
            interpret=interp,
        )
    )
    t_bwd = time_fn(bwd, q, k, v, do, o, lse)
    bwd_bytes = flash.bwd_dma_bytes(b, hq, hkv, s, s, d, 4, block_q=bq, block_k=bk)
    out.append(
        row("train_bwd_attn", t_bwd, bwd_bytes,
            f"[dq + dkv pallas sweeps, {t_bwd/t_fwd:.2f}x fwd time]",
            phase="bwd_attn", plan_mode="flash_bwd", measured="pallas",
            block_q=bq, block_k=bk, plan_bytes=plan.bytes_moved)
    )


def _ffn_phase_rows(out: list[str]) -> None:
    """Chunked-FFN forward and backward, algorithmic byte accounting:
    weights streamed once per chunk pass + activations read/written."""
    cfg = configs.get_config("qwen2-7b-smoke").with_(dtype="float32")
    b, s = (2, 128) if smoke() else (4, 1024)
    d, f = cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(1)
    p = mlp.mlp_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(key, (b, s, d), jnp.float32)
    n_w = 3 if cfg.act in ("swiglu", "geglu") else 2
    # fwd: x in, weights once, hidden (B,S,F) written+read, out written
    fwd_bytes = 4 * (n_w * d * f + 2 * b * s * d + 2 * b * s * f)
    # bwd: the same streams again for dx plus a second pass for dw
    bwd_bytes = 2 * fwd_bytes

    fwd = jax.jit(lambda xx: mlp.mlp_apply(p, cfg, xx))
    t_fwd = time_fn(fwd, x)
    out.append(
        row("train_fwd_ffn", t_fwd, fwd_bytes, "[dense FFN fwd]",
            phase="fwd", plan_mode="ffn", measured="xla")
    )
    bwd = jax.jit(jax.grad(lambda xx: mlp.mlp_apply(p, cfg, xx).sum()))
    t_bwd = time_fn(bwd, x)
    out.append(
        row("train_bwd_ffn", t_bwd, bwd_bytes,
            f"[FFN grad, {t_bwd/t_fwd:.2f}x fwd time]",
            phase="bwd_ffn", plan_mode="ffn", measured="xla")
    )


def _train_step_bytes(cfg, b: int, s: int) -> int:
    """Algorithmic per-step traffic shared by both train-step rows: every
    parameter read for fwd, read for bwd, and grad+moments written/read by
    AdamW (3 param-sized streams), plus the residual stream activations
    once per layer per direction."""
    n_params = sum(
        int(jnp.prod(jnp.array(l.shape)))
        for l in jax.tree.leaves(tf.abstract_params(cfg))
    )
    item = 4  # fp32 benchmark dtype
    act = 2 * cfg.n_layers * 2 * b * s * cfg.d_model * item
    return 5 * n_params * item + act


def _train_rows(out: list[str]) -> None:
    """Monolithic vs blockwise-parallel train step (tokens/s/device)."""
    base = configs.get_config("qwen2-7b-smoke").with_(dtype="float32")
    # train_4k-proportioned: sequence-dominant batch (memory-limited regime)
    b, s, chunk = (2, 128, 32) if smoke() else (4, 1024, 256)
    oc = adamw.OptConfig(lr=1e-3)
    tok = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, base.vocab)
    lab = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, base.vocab)
    batch = {"tokens": tok, "labels": lab}
    n_dev = jax.device_count()
    nbytes = _train_step_bytes(base, b, s)
    out.append(f"# train shapes b={b} s={s} chunk={chunk} devices={n_dev}")

    times = {}
    for name, cfg in (
        ("train_step_monolithic", base),
        ("train_step_blockwise",
         base.with_(blockwise=True, blockwise_chunk=chunk,
                    remat_policy="nothing_saveable")),
    ):
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        step = jax.jit(trainer.make_train_step(cfg, oc, None))
        t = time_fn(step, params, opt, batch)
        times[name] = t
        tps = b * s / t / n_dev
        note = f"[{tps:.0f} tok/s/dev]"
        extra = {}
        if name == "train_step_blockwise":
            ratio = times["train_step_monolithic"] / t
            note = f"[{tps:.0f} tok/s/dev, {ratio:.2f}x vs monolithic]"
            extra = {"improvement_vs_monolithic": round(ratio, 3),
                     "q_chunk": chunk}
        out.append(
            row(name, t, nbytes, note,
                phase="step", plan_mode=name.split("_")[-1], measured="xla",
                cell="train_4k", tokens=b * s,
                tokens_per_s_device=round(tps, 2), **extra)
        )


def run():
    """Suite entry point (benchmarks.run)."""
    out: list[str] = []
    _attn_phase_rows(out)
    _ffn_phase_rows(out)
    _train_rows(out)
    return out
