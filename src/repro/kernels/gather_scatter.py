"""Index-set read/write kernels (paper §III-A "specified set of indices").

The paper's basic access kernels support gathering/scattering rows by an
index table; in CUDA the table lives in constant memory.  On TPU the table
is **scalar-prefetched** (`pltpu.PrefetchScalarGridSpec`): it lands in SMEM
before the grid runs, and the BlockSpec index_map reads it to choose which
row block each grid step DMAs.  This is the exact functional analogue of
constant memory: small, uniformly read metadata off the datapath.

This kernel is the framework's MoE dispatch/combine primitive: token
permutation by expert id is precisely an index-set gather (DESIGN.md §4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import cdiv, force_interpret, plan_copy_tiles


def _copy_row_kernel(idx_ref, x_ref, o_ref):
    del idx_ref  # consumed by the index maps
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def gather_rows(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """out[i, :] = x[idx[i], :].  idx: int32 (num_out,)."""
    if x.ndim != 2 or idx.ndim != 1:
        raise ValueError(f"gather_rows wants 2-D x and 1-D idx, got {x.shape}, {idx.shape}")
    n_out = idx.shape[0]
    C = x.shape[1]
    bc = min(block_c or plan_copy_tiles(1, C, x.dtype).block_c, C)
    nC = cdiv(C, bc)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out, nC),
        in_specs=[pl.BlockSpec((1, bc), lambda i, j, idx_ref: (idx_ref[i], j))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, idx_ref: (i, j)),
    )
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, C), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def scatter_rows(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """out[idx[i], :] = x[i, :].  ``idx`` must be a permutation of
    range(x.shape[0]) — every output row is written exactly once."""
    if x.ndim != 2 or idx.ndim != 1 or idx.shape[0] != x.shape[0]:
        raise ValueError(f"scatter_rows wants idx over rows, got {x.shape}, {idx.shape}")
    n = x.shape[0]
    C = x.shape[1]
    bc = min(block_c or plan_copy_tiles(1, C, x.dtype).block_c, C)
    nC = cdiv(C, bc)

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, nC),
        in_specs=[pl.BlockSpec((1, bc), lambda i, j, idx_ref: (i, j))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, idx_ref: (idx_ref[i], j)),
    )
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, C), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x)
