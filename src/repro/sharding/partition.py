"""Parameter/activation/cache sharding rules (DP x TP + optional pod axis).

Rules are name-based on parameter paths — Megatron-style TP on the
'model' axis (column-parallel in, row-parallel out), experts EP- or
TP-sharded, batch on ('pod','data'), optional FSDP ('data' added to the
largest replicated weight axis), ZeRO-1 on optimizer moments.  All specs
are plain PartitionSpecs resolved against whatever mesh the caller
installs, so the same model code runs on 1 device (empty specs) or the
2x16x16 production mesh.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# parameter-name classes
_COL_LAST = {
    "w_qkv", "w_q", "w_kv", "w_o_gate", "w_zifo", "w_gate_branch",
    "w_rnn_in", "lm_head", "b_qkv",
}
_ROW_SECOND = {"w_o", "w_out"}
_REPLICATED = {"scale", "bias", "lam", "conv_w", "w_router", "r_zifo"}


def param_spec(path: str, shape: tuple[int, ...], *, cfg, mesh_axes: dict) -> P:
    """PartitionSpec for one parameter, by path pattern.  Stage-stacked
    leaves carry a leading (count,) axis which is never sharded."""
    model = "model"
    data = "data"
    msize = mesh_axes.get("model_size", 1)
    dsize = mesh_axes.get("data_size", 1)
    nd = len(shape)
    name = path.split("/")[-1]
    staged = "/stages/" in path or path.startswith("stages/")
    spec: list = [None] * nd

    def div(ax: int, size: int) -> bool:
        return size > 1 and shape[ax] % size == 0 and shape[ax] >= size

    is_expert = (
        cfg is not None
        and cfg.moe is not None
        and name in ("w_up", "w_gate", "w_down")
        and nd >= 3
        and "moe" in path
    )
    if is_expert:
        e_ax = nd - 3
        if cfg.moe.shard == "expert" and div(e_ax, msize):
            spec[e_ax] = model
        else:
            ff_ax = nd - 1 if name in ("w_up", "w_gate") else nd - 2
            if div(ff_ax, msize):
                spec[ff_ax] = model
    elif name in _COL_LAST or name in ("w_up", "w_gate"):
        if div(nd - 1, msize):
            spec[nd - 1] = model
    elif name in _ROW_SECOND or name == "w_down":
        if nd >= 2 and div(nd - 2, msize):
            spec[nd - 2] = model
    elif name == "tok":
        # Vocab-parallel. D-sharding would make the scatter-add gradient
        # comm-free, but XLA 0.8.2's SPMD partitioner mis-compiles the
        # dim-sharded gather inside the grad-accumulation while loop
        # ("Slice dim size > dynamic slice dimension" verifier error), so
        # V-sharding it is; the fp32 embed-grad all-reduce this causes is
        # a known, once-per-step cost (EXPERIMENTS.md §Perf).
        if div(0, msize):
            spec[0] = model
    elif name in _REPLICATED:
        return P(*spec)

    # FSDP: shard the largest remaining replicated axis over data
    if (
        cfg is not None
        and getattr(cfg, "fsdp", False)
        and nd >= 2
        and name != "tok"
        and name not in _REPLICATED
    ):
        start = 1 if staged else 0
        free = [i for i in range(start, nd) if spec[i] is None]
        if free:
            ax = max(free, key=lambda i: shape[i])
            if div(ax, dsize):
                spec[ax] = data
    return P(*spec)


def tree_pspecs(tree_shapes: Any, *, cfg, mesh_axes: dict) -> Any:
    """Map a params pytree (arrays or ShapeDtypeStructs) to PartitionSpecs."""

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, f"{path}/{i}") for i, v in enumerate(tree)]
            return type(tree)(t)
        return param_spec(path, tuple(tree.shape), cfg=cfg, mesh_axes=mesh_axes)

    return walk(tree_shapes, "")


def zero1_spec(pspec: P, shape: tuple[int, ...], *, data_axis: str, data_size: int) -> P:
    """ZeRO-1: additionally shard optimizer moments over the data axis on
    the first unsharded, divisible axis.  No-op when the param spec
    already uses the data axis (FSDP weights are already data-sharded)."""
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    used = any(
        s == data_axis or (isinstance(s, tuple) and data_axis in s) for s in spec
    )
    if data_size > 1 and not used:
        for i, s in enumerate(spec):
            if s is None and shape[i] % data_size == 0 and shape[i] >= data_size:
                spec[i] = data_axis
                break
    return P(*spec)


def opt_pspecs(param_specs: Any, param_shapes: Any, *, mesh_axes: dict) -> Any:
    """Optimizer-state specs: moments get ZeRO-1, step replicated."""
    dsize = mesh_axes.get("data_size", 1)

    def z1(spec, shp):
        return zero1_spec(spec, tuple(shp.shape), data_axis="data", data_size=dsize)

    mom = jax.tree.map(z1, param_specs, param_shapes)
    return {"m": mom, "v": mom, "step": P()}


def batch_pspec(batch_size: int, mesh) -> P | None:
    """Shard the batch axis over (pod, data) when divisible, else None."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if total > 1 and batch_size % total == 0:
        return tuple(axes)
    # partial: try data only
    if "data" in mesh.axis_names and batch_size % mesh.shape["data"] == 0 and mesh.shape["data"] > 1:
        return ("data",)
    return None


def cache_leaf_spec(shape: tuple[int, ...], batch_axes, *, model_size: int) -> P:
    """Decode-cache leaf: (count, B, ...) — batch on data axes; one
    inner axis on 'model' (prefer heads, then sequence, then features)."""
    nd = len(shape)
    spec: list = [None] * nd
    if nd >= 2 and batch_axes is not None:
        spec[1] = batch_axes
    if model_size > 1:
        for ax in range(2, nd):
            if shape[ax] % model_size == 0 and shape[ax] >= model_size:
                spec[ax] = "model"
                break
    return P(*spec)


def filter_spec(spec: P, axis_names) -> P:
    """Drop mesh-axis names not present in the ambient mesh (lets model
    code write canonical specs like P(('pod','data'), 'model') that
    degrade gracefully on smaller meshes)."""

    def fix(el):
        if el is None:
            return None
        if isinstance(el, str):
            return el if el in axis_names else None
        t = tuple(a for a in el if a in axis_names)
        return t if t else None

    return P(*[fix(e) for e in spec])


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op without an ambient mesh
    and tolerant of missing axes."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        return jax.lax.with_sharding_constraint(
            x, filter_spec(spec, mesh.axis_names)
        )
    except Exception:
        return x


BATCH = ("pod", "data")


def residual_spec(cfg, ndim: int = 3) -> P:
    """Residual-stream spec between blocks.  With cfg.sp (Megatron
    sequence parallelism) the sequence dim is sharded on 'model': the
    row-parallel output all-reduce becomes reduce-scatter, and the
    (cheaper, bf16) all-gather happens after the norm — ~25% less wire
    traffic per layer and norms/residual ops run on S/tp tokens."""
    if getattr(cfg, "sp", False):
        return P(*([BATCH, "model"] + [None] * (ndim - 2)))
    return P(*([BATCH] + [None] * (ndim - 1)))


def replicated_spec(ndim: int = 3) -> P:
    """Batch-sharded, otherwise-replicated activation spec (the default
    residual-stream layout when sequence parallelism is off)."""
    return P(*([BATCH] + [None] * (ndim - 1)))


def ep_param_specs(params: Any, axis: str) -> Any:
    """Expert-parallel PartitionSpec tree for a MoE layer's params: the
    expert-stacked weights (``w_up``/``w_gate``/``w_down``) shard their
    leading expert axis over mesh ``axis``; router, norms, and any shared
    expert stay replicated.  This is the in_specs tree
    ``models.moe.moe_sort_ep`` feeds `shard_map` (DESIGN.md §10)."""
    specs = jax.tree.map(lambda _: P(), params)
    for name in ("w_up", "w_gate", "w_down"):
        if name in specs:
            specs[name] = P(axis)
    return specs
