"""Benchmark-regression gate (``make bench-check``).

Three checks, in order:

1. **Structure** — every committed ``BENCH_*.json`` parses, carries a
   positive ``memcpy_gbps`` baseline, and every row has the harness
   schema (``op/us_per_call/gbps/frac_memcpy/suite``).  A PR that breaks
   the record stream fails here.
2. **Measured-path ratios** — the plan-engine comparisons the committed
   files exist to track (fused vs per-sweep stencil, IndexPlan vs seed
   rowwise MoE dispatch, engine vs seed head permutes, halo-blocked vs
   per-sweep distributed stencil, split-KV vs one-shot decode
   attention, blockwise-parallel vs monolithic train step) must stay
   above a tolerance-banded
   floor.  The floors sit well below the currently-measured ratios, so
   noise passes but a silent engine regression (or a hand-edited JSON)
   exits nonzero.
3. **Smoke replay** (skippable with ``--no-smoke``) — re-runs the whole
   harness via ``python -m benchmarks.run --smoke`` (tiny deterministic
   shapes) into a temp dir, then checks the fresh records against the
   committed files' structure: same suites, same row schema.  Fresh
   ratios are evaluated against the same floors but only *warn* — smoke
   shapes are interpret-scale and noisy — and everything lands in the
   ``--out`` diff artifact for the (non-blocking) CI job to upload.

Usage::

    PYTHONPATH=src python tools/check_bench.py [--root .] [--no-smoke]
        [--out bench-check.json]

Exit status: nonzero on any structure failure or committed-ratio
regression; smoke warnings never fail the gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import subprocess
import sys
import tempfile

ROW_SCHEMA = ("op", "us_per_call", "gbps", "frac_memcpy", "suite")

BENCH_FILES = (
    "BENCH_rearrange.json",
    "BENCH_stencil.json",
    "BENCH_moe.json",
    "BENCH_dist.json",
    "BENCH_serve.json",
    "BENCH_train.json",
)

# (file, numerator op regex, denominator op regex, floor): the measured
# GB/s ratio num/den must stay >= floor.  Floors are tolerance-banded —
# set well under the committed ratios (shown) so run-to-run noise passes
# while a regression of the engine (or an injected edit) fails.
RATIO_POLICIES = (
    # fused temporal blocking vs per-sweep, kernel-measured (~3.6x committed)
    ("BENCH_stencil.json",
     r"jacobi\d+_interp_fused_k\d+", r"jacobi\d+_interp_per_sweep_k\d+", 1.2),
    # IndexPlan blocked+fused dispatch vs seed rowwise (~16x committed)
    ("BENCH_moe.json",
     r"moe_dispatch_sort_fused", r"moe_dispatch_sort_rowwise", 2.0),
    # plan-engine head permutes vs seed generic kernel (~1.9x / ~55x)
    ("BENCH_rearrange.json",
     r"split_heads_engine", r"split_heads_seed_generic", 1.0),
    ("BENCH_rearrange.json",
     r"merge_heads_engine", r"merge_heads_seed_generic", 1.0),
    # closed-form analytic plan vs the heuristic engine timing (ISSUE 8,
    # DESIGN.md §14): by the bit-identity contract both rows execute the
    # SAME plan object when the derivation matched the route, so the true
    # ratio is 1.0 and the floor is purely the run-to-run noise band —
    # "matches or beats", tolerance-banded, not a perf target
    ("BENCH_rearrange.json",
     r"split_heads_analytic", r"split_heads_engine", 0.9),
    ("BENCH_rearrange.json",
     r"merge_heads_analytic", r"merge_heads_engine", 0.9),
    # halo-blocked distributed stencil vs per-sweep exchanges (~3x committed)
    ("BENCH_dist.json",
     r"stencil_halo_blocked_k\d+", r"stencil_per_sweep_k\d+", 1.0),
    # split-KV two-stage decode vs the one-shot kernel at sq=1 (both
    # interpret-measured with identical byte accounting, so this is a
    # pure time ratio; ISSUE 6 floor: >= 1.0 even in smoke)
    ("BENCH_serve.json",
     r"decode_splitkv_interp", r"decode_oneshot_interp", 1.0),
    # blockwise-parallel vs monolithic train step at the train_4k-
    # proportioned shape (same byte accounting => pure time ratio).  The
    # blockwise path buys peak-activation memory; the gate asserts the
    # throughput cost stays inside the tolerance band (ISSUE 7 floor).
    ("BENCH_train.json",
     r"train_step_blockwise", r"train_step_monolithic", 0.7),
)


def load(path: pathlib.Path) -> tuple[dict | None, list[str]]:
    """Parse one benchmark JSON; (doc, errors)."""
    try:
        doc = json.loads(path.read_text())
    except FileNotFoundError:
        return None, [f"{path.name}: missing"]
    except ValueError as e:
        return None, [f"{path.name}: unparseable ({e})"]
    errs = []
    if not isinstance(doc.get("memcpy_gbps"), (int, float)) or doc["memcpy_gbps"] <= 0:
        errs.append(f"{path.name}: memcpy_gbps baseline missing or non-positive")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errs.append(f"{path.name}: no rows")
        return doc, errs
    for i, r in enumerate(rows):
        missing = [k for k in ROW_SCHEMA if k not in r]
        if missing:
            errs.append(f"{path.name}: row {i} ({r.get('op', '?')}) missing {missing}")
        elif not isinstance(r["us_per_call"], (int, float)) or r["us_per_call"] <= 0:
            errs.append(f"{path.name}: row {i} ({r['op']}) bad us_per_call")
    return doc, errs


def _find(rows: list[dict], pattern: str) -> dict | None:
    rx = re.compile(pattern + r"\Z")
    for r in rows:
        if rx.match(str(r.get("op", ""))):
            return r
    return None


def check_ratios(docs: dict[str, dict]) -> tuple[list[str], list[dict]]:
    """Evaluate every ratio policy against loaded docs; (errors, report)."""
    errs, report = [], []
    for fname, num_rx, den_rx, floor in RATIO_POLICIES:
        doc = docs.get(fname)
        if doc is None:
            continue
        rows = doc.get("rows") or []
        num, den = _find(rows, num_rx), _find(rows, den_rx)
        if num is None or den is None:
            errs.append(f"{fname}: ratio rows missing ({num_rx} / {den_rx})")
            continue
        if not isinstance(num.get("gbps"), (int, float)):
            errs.append(f"{fname}: {num['op']} has no GB/s field")
            continue
        if not den.get("gbps"):
            errs.append(f"{fname}: {den['op']} has zero GB/s")
            continue
        ratio = num["gbps"] / den["gbps"]
        report.append({
            "file": fname, "num": num["op"], "den": den["op"],
            "ratio": round(ratio, 3), "floor": floor, "ok": ratio >= floor,
        })
        if ratio < floor:
            errs.append(
                f"{fname}: {num['op']} / {den['op']} = {ratio:.2f} "
                f"below floor {floor} — measured-path regression"
            )
    return errs, report


def run_smoke(root: pathlib.Path, tmp: pathlib.Path) -> tuple[dict[str, dict], list[str]]:
    """Replay the harness in --smoke mode; returns (fresh docs, errors)."""
    paths = {f: tmp / f for f in BENCH_FILES}
    cmd = [
        sys.executable, "-m", "benchmarks.run", "--smoke",
        "--json", str(paths["BENCH_rearrange.json"]),
        "--json-stencil", str(paths["BENCH_stencil.json"]),
        "--json-moe", str(paths["BENCH_moe.json"]),
        "--json-dist", str(paths["BENCH_dist.json"]),
        "--json-serve", str(paths["BENCH_serve.json"]),
        "--json-train", str(paths["BENCH_train.json"]),
    ]
    r = subprocess.run(
        cmd, cwd=root, capture_output=True, text=True, timeout=3600
    )
    if r.returncode != 0:
        return {}, [
            "smoke run failed "
            f"(exit {r.returncode}):\n{r.stdout[-1000:]}\n{r.stderr[-2000:]}"
        ]
    docs, errs = {}, []
    for fname, p in paths.items():
        doc, ferrs = load(p)
        errs.extend(f"smoke {e}" for e in ferrs)
        if doc is not None:
            docs[fname] = doc
    return docs, errs


def compare_structure(
    committed: dict[str, dict], fresh: dict[str, dict]
) -> list[str]:
    """The fresh smoke records must cover the committed files' shape: same
    suite sets per file (the harness still runs everything) and no row
    schema drift."""
    errs = []
    for fname, cdoc in committed.items():
        fdoc = fresh.get(fname)
        if fdoc is None:
            errs.append(f"smoke produced no {fname}")
            continue
        csuites = {r.get("suite") for r in cdoc.get("rows", [])}
        fsuites = {r.get("suite") for r in fdoc.get("rows", [])}
        if not csuites <= fsuites:
            errs.append(
                f"{fname}: smoke run lost suites {sorted(csuites - fsuites)}"
            )
    return errs


def main(argv: list[str] | None = None) -> int:
    """Entry point; returns the process exit status."""
    ap = argparse.ArgumentParser(prog="check_bench")
    ap.add_argument("--root", default=".", help="repo root with BENCH_*.json")
    ap.add_argument("--no-smoke", action="store_true",
                    help="skip the smoke replay (structure + ratios only)")
    ap.add_argument("--out", default="", help="write the diff artifact here")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root)

    failures: list[str] = []
    warnings: list[str] = []
    docs: dict[str, dict] = {}
    for fname in BENCH_FILES:
        doc, errs = load(root / fname)
        failures.extend(errs)
        if doc is not None:
            docs[fname] = doc

    ratio_errs, ratio_report = check_ratios(docs)
    failures.extend(ratio_errs)

    smoke_report: list[dict] = []
    if not args.no_smoke:
        with tempfile.TemporaryDirectory(prefix="bench-smoke-") as td:
            fresh, errs = run_smoke(root, pathlib.Path(td))
            failures.extend(errs)
            if fresh:
                failures.extend(compare_structure(docs, fresh))
                smoke_errs, smoke_report = check_ratios(fresh)
                # fresh interpret-scale timings only warn — the committed
                # trajectory is the gate, the smoke run proves the harness
                warnings.extend(f"smoke: {e}" for e in smoke_errs)

    artifact = {
        "failures": failures,
        "warnings": warnings,
        "committed_ratios": ratio_report,
        "smoke_ratios": smoke_report,
    }
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(artifact, indent=1) + "\n")

    for w in warnings:
        print(f"WARN: {w}")
    for f in failures:
        print(f"FAIL: {f}")
    for r in ratio_report:
        print(
            f"ratio {r['file']}: {r['num']}/{r['den']} = {r['ratio']} "
            f"(floor {r['floor']}) {'ok' if r['ok'] else 'REGRESSED'}"
        )
    if failures:
        print(f"bench-check: {len(failures)} failure(s)")
        return 1
    print("bench-check: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
