"""Elastic scaling + failure handling policy.

At 1000+ node scale, the failure model is: a pod loses hosts, the job is
rescheduled on a different device count, and training must resume from
the last checkpoint with a RESHAPED mesh.  The pieces that make this
work here:

  * checkpoints are mesh-agnostic (host numpy + manifest;
    ``Checkpointer.restore`` device_puts with the NEW mesh's shardings);
  * the data pipeline is stateless (batch = f(seed, step, shard)) so any
    host count re-derives its shard;
  * ``plan_mesh`` picks the largest valid (data, model) factorization of
    whatever devices survive, preferring to shrink the data axis (model
    parallel width is fixed by the checkpointed layout, so data-parallel
    width absorbs the loss);
  * straggler mitigation is structural: all collectives are sized by the
    static sharding (no data-dependent shapes), grad accumulation keeps
    per-device steps uniform, and the synchronous step means one slow
    host delays — never corrupts — the step.  Detection hooks
    (``StepTimer``) flag hosts whose step time exceeds the p99 window so
    the scheduler can evict them at the next checkpoint boundary.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax


def plan_mesh_shape(n_devices: int, model_width: int, *, pods: int = 1):
    """(shape, axes) for a surviving device count.  model_width is fixed
    by the checkpoint layout; data absorbs the change.  Pure function —
    no device state touched (callable from schedulers/tests)."""
    if n_devices % (model_width * pods):
        # drop stragglers to the largest multiple (scheduler evicts extras)
        n_devices = (n_devices // (model_width * pods)) * model_width * pods
    data = n_devices // (model_width * pods)
    if data < 1:
        raise ValueError(
            f"{n_devices} devices cannot host model_width={model_width}"
        )
    shape = (pods, data, model_width) if pods > 1 else (data, model_width)
    axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return shape, axes


def plan_mesh(n_devices: int, model_width: int, *, pods: int = 1):
    """Build the mesh for :func:`plan_mesh_shape`'s chosen layout."""
    from repro.launch.mesh import make_mesh_compat

    shape, axes = plan_mesh_shape(n_devices, model_width, pods=pods)
    return make_mesh_compat(shape, axes)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-device microbatch constant across a rescale when possible;
    otherwise keep global batch and adjust grad-accum."""
    per_dev = global_batch // old_data
    return per_dev * new_data


@dataclass
class StepTimer:
    """Rolling straggler detector: flags steps beyond k x median."""

    window: int = 50
    k: float = 3.0

    def __post_init__(self):
        self.times: list[float] = []
        self._t0: float | None = None

    def start(self):
        """Mark the beginning of a step."""
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Returns True if this step looks like a straggler event."""
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        self.times = self.times[-self.window :]
        med = sorted(self.times)[len(self.times) // 2]
        return len(self.times) >= 10 and dt > self.k * med
