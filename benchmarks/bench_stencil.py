"""Paper Fig. 2 / Table 4: 2-D FD stencil, orders I..IV, 4096^2 fp32 —
plus the stencil plan engine's fused-vs-per-sweep comparison (DESIGN.md §9).

The fused rows run a ``repeat(k)`` Jacobi program (one temporally-blocked
kernel); the per-sweep rows run the same k sweeps as k separate stencil
calls.  Effective bandwidth is normalized to the *useful* algorithmic
traffic of the per-sweep schedule (k reads + k writes), so the fused row's
higher GB/s directly reports the HBM round trips it deleted.  Rows land in
``BENCH_stencil.json`` (see benchmarks/run.py) with the plan metadata.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, smoke, time_fn
from repro.core import stencil as st
from repro.kernels import ops

JACOBI = st.Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25, 0.25, 0.25, 0.25))
SWEEPS = 8


def _fused_vs_per_sweep(out: list[str], n: int, k: int, tag: str = "") -> None:
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((n, n)), jnp.float32
    )
    useful = 2 * x.nbytes * k  # k sweeps x (read + write): the per-sweep basis
    prog = JACOBI.repeat(k)
    plan = prog.compile(x.shape, x.dtype)

    def per_sweep(a):
        for _ in range(k):
            a = JACOBI(a)
        return a

    measured = "pallas" if ops.use_pallas() else "xla_oracle"
    t = time_fn(jax.jit(per_sweep), x)
    out.append(
        row(
            f"jacobi{n}{tag}_per_sweep_k{k}", t, useful,
            variant="per_sweep", k=k, size=n, plan_mode="reference",
            measured=measured,
        )
    )
    t = time_fn(jax.jit(prog), x)
    out.append(
        row(
            f"jacobi{n}{tag}_fused_k{k}", t, useful,
            f"[plan {plan.bytes_per_sweep_path / max(plan.bytes_moved, 1):.1f}x]",
            variant="fused", k=k, size=n, plan_mode=plan.mode,
            measured=measured,
            plan_source="heuristic",
            plan_bytes_fused=plan.bytes_moved,
            plan_bytes_per_sweep=plan.bytes_per_sweep_path,
        )
    )
    # the autotuned panel next to the heuristic one (DESIGN.md §11)
    plan_t = prog.compile(x.shape, x.dtype, tuned=True)
    if plan_t.mode == "fused":
        fn_t = jax.jit(
            lambda a, p=plan_t: ops.stencil_program(
                a, p.stages_exec, boundary="zero",
                block_rows=p.block_rows or None, fused=True,
            )
        )
        t_t = time_fn(fn_t, x)
        out.append(
            row(
                f"jacobi{n}{tag}_tuned_k{k}", t_t, useful,
                f"[panel {plan_t.block_rows} vs {plan.block_rows} heuristic, "
                f"{t/t_t:.2f}x]",
                variant="fused", k=k, size=n, plan_mode=plan_t.mode,
                measured=measured,
                plan_source="tuned",
                panel=plan_t.block_rows,
                panel_heuristic=plan.block_rows,
                improvement_vs_heuristic=round(t / t_t, 3),
            )
        )


def run() -> list[str]:
    out = []
    side = 128 if smoke() else 4096
    x = jnp.asarray(np.random.default_rng(0).standard_normal((side, side)), jnp.float32)
    nbytes = 2 * x.nbytes  # in + out (the stencil reads each cell ~1x via halo reuse)
    for order in (1, 2, 3, 4):
        s = st.fd_laplacian(order)
        fn = jax.jit(lambda a, s=s: s(a))
        t = time_fn(fn, x)
        out.append(row(f"fd_stencil_order{order}", t, nbytes, f"[{len(s.offsets)}pt]"))
    # generic functor variant (paper's template mechanism): box blur
    blur = st.box_blur(1)
    t = time_fn(jax.jit(lambda a: blur(a)), x)
    out.append(row("box_blur_3x3", t, nbytes))

    # fused repeat(k) programs vs k separate sweeps, two problem sizes
    sweeps = 4 if smoke() else SWEEPS
    for n in (128,) if smoke() else (2048, 4096):
        _fused_vs_per_sweep(out, n, sweeps)

    # the same comparison driven through the actual Pallas kernel (interpret
    # mode off-TPU) on a small grid, so the fused kernel itself is measured
    if jax.devices()[0].platform != "tpu":
        prior = os.environ.get("REPRO_PALLAS_INTERPRET")
        os.environ["REPRO_PALLAS_INTERPRET"] = "1"
        try:
            _fused_vs_per_sweep(out, 64 if smoke() else 512, sweeps, tag="_interp")
        finally:
            if prior is None:
                os.environ.pop("REPRO_PALLAS_INTERPRET", None)
            else:
                os.environ["REPRO_PALLAS_INTERPRET"] = prior
    return out
