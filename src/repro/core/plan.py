"""Rearrangement planner: collapse -> route -> cache (DESIGN.md §3).

The planner is the library's 'auto gridding' (paper §III-A: "gridding and
threading configuration is done automatically based on the data size") and
the single dispatch spine for every permute-shaped op:

1. **collapse** — merge contiguous input axes that stay adjacent under the
   permutation (:func:`repro.core.layout.coalesce`), so every reorder
   reduces to its minimal-rank canonical form;
2. **route** — pick the cheapest kernel for the canonical form:
   ``identity`` (pure reshape, no data movement), ``transpose`` (the
   adjacent-swap family -> batched 2-D transpose, `kernels/permute3d.py`),
   ``copy`` (fastest axis preserved -> blocked row gather), or ``reorder``
   (generic fallback, `kernels/reorder_nd.py`);
3. **cache** — plans are memoized on ``(shape, dtype, perm, grid_order)``
   so steady-state training/serving steps pay zero planning overhead
   (repeated calls return the *identical* plan object).

It also reports the predicted HBM traffic and roofline time so callers
(and the benchmarks) can compare achieved vs predicted movement.

``tuned=`` adds the optional fourth step (DESIGN.md §11): the routed
plan's tile neighborhood is enumerated and the autotuner
(:mod:`repro.core.tune`) selects by measurement (TPU) or by the roofline
cost model (deterministic fallback).  The untuned default is bit-identical
to the pre-tuner planner; a tuned plan differs only in tiles / grid
order, never in the computed result.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Sequence

import jax.numpy as jnp

from repro.core import affine, layout, tune
from repro.kernels.tiling import (
    TilePlan,
    VecTilePlan,
    cdiv,
    copy_tile_candidates,
    plan_copy_tiles,
    plan_transpose_tiles,
    plan_transpose_vec_tiles,
    transpose_tile_candidates,
    vec_tile_candidates,
)
from repro.utils.roofline import movement_cost_s

# v5e per-chip hardware constants (also used by utils.roofline)
HBM_GBPS = 819.0
PEAK_BF16_TFLOPS = 197.0
ICI_GBPS_PER_LINK = 50.0


@dataclass(frozen=True)
class RearrangePlan:
    """Cached lowering decision for one permutation: the canonical
    (collapsed) form, the kernel route, the chosen tiles, and the predicted
    HBM traffic/roofline (DESIGN.md §3)."""

    mode: str  # identity | copy | transpose | reorder | affine
    kernel: str  # noop | copy | transpose2d_batched[_vec] | reorder_nd | reorder_affine
    canonical_shape: tuple[int, ...]
    canonical_perm: tuple[int, ...]
    out_shape: tuple[int, ...]  # full-rank output shape
    exec_shape: tuple[int, ...] | None  # (B, R, C, V) for transpose mode
    block_r: int
    block_c: int
    grid_order: str
    bytes_moved: int  # read + write
    roofline_s: float  # bytes / HBM bandwidth (one chip)
    block_v: int | None = None  # lane-depth tile on the _vec route
    plan_source: str = "heuristic"  # heuristic | analytic | tuned
    amap: affine.AffineMap | None = None  # merged map, affine-mode plans

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        tiles = f"tiles=({self.block_r},{self.block_c}"
        tiles += f",{self.block_v})" if self.block_v is not None else ")"
        ex = f" exec={self.exec_shape}" if self.exec_shape is not None else ""
        return (
            f"{self.mode}: shape={self.canonical_shape} perm={self.canonical_perm} "
            f"kernel={self.kernel} {tiles}{ex} source={self.plan_source} "
            f"{self.bytes_moved/1e6:.2f} MB moved, "
            f"roofline {self.roofline_s*1e6:.1f} us @ {HBM_GBPS} GB/s"
        )


def _build_plan(
    shape: tuple[int, ...],
    dtype_name: str,
    perm: tuple[int, ...],
    grid_order: str,
    block_r: int | None = None,
    block_c: int | None = None,
) -> RearrangePlan:
    """Collapse + route one permutation and materialize the plan.

    ``block_r`` / ``block_c`` override the heuristic tiles (the tuner's
    hook); with both ``None`` this is exactly the pre-tuner planner.
    """
    canon = layout.canonicalize(shape, perm)
    itemsize = jnp.dtype(dtype_name).itemsize
    n_elems = 1
    for s in shape:
        n_elems *= int(s)
    out_shape = tuple(shape[p] for p in perm)
    bytes_moved = 2 * n_elems * itemsize  # read once + write once

    exec_shape = None
    block_v = None
    factors = None if canon.mode == "identity" else layout.swap_factors(
        canon.shape, canon.perm
    )
    if n_elems == 0:
        # zero-size array: nothing to move, the output is an empty reshape
        return RearrangePlan(
            mode="identity",
            kernel="noop",
            canonical_shape=canon.shape,
            canonical_perm=canon.perm,
            out_shape=out_shape,
            exec_shape=None,
            block_r=1,
            block_c=1,
            grid_order=grid_order,
            bytes_moved=0,
            roofline_s=0.0,
        )
    if canon.mode == "identity" or canon.rows_axis is None:
        # no movement: the output is a metadata reshape of the input (a
        # caller that must materialize routes through the streaming copy
        # kernel, copy.py, with these tiles)
        mode, kernel = "identity", "noop"
        last = shape[-1] if shape else 1
        tp = plan_copy_tiles(max(n_elems // max(last, 1), 1), last, dtype_name)
        br, bc = tp.block_r, tp.block_c
    elif factors is not None:
        # adjacent-swap family: batched 2-D transpose plane, V-deep elements
        mode = "transpose"
        b, r, c, v = factors
        exec_shape = (b, r, c, v)
        if v > 1:
            kernel = "transpose2d_batched_vec"
            vp = plan_transpose_vec_tiles(r, c, v, dtype_name)
            br, bc = vp.block_r, vp.block_c
            block_v = vp.block_v
        else:
            kernel = "transpose2d_batched"
            tp = plan_transpose_tiles(r, c, dtype_name)
            br, bc = tp.block_r, tp.block_c
    elif canon.mode == "copy":
        # fastest axis preserved: blocked gather of contiguous rows
        mode, kernel = "copy", "reorder_nd"
        tp = plan_copy_tiles(
            canon.shape[canon.rows_axis], canon.shape[canon.cols_axis], dtype_name
        )
        br, bc = tp.block_r, tp.block_c
    else:
        # generic fallback: both fastest axes change, not a single swap
        mode, kernel = "reorder", "reorder_nd"
        tp = plan_transpose_tiles(
            canon.shape[canon.rows_axis], canon.shape[canon.cols_axis], dtype_name
        )
        br, bc = tp.block_r, tp.block_c

    if block_r is not None:
        br = block_r
    if block_c is not None:
        bc = block_c
    source = "heuristic"
    if block_r is None and block_c is None:
        # analytic cross-check (DESIGN.md §14): derive the tile in closed
        # form from the affine lift; when it reproduces the routed tile the
        # plan is stamped `analytic` (the common case — the derivation uses
        # the same formulas on the merged run-lengths).  A mismatch (e.g. a
        # size-1 axis splitting a mergeable run, where the affine merge is
        # coarser than `coalesce`) keeps the authoritative heuristic stamp;
        # the plan itself is identical either way.
        try:
            ex = affine.derive(layout.to_affine(shape, perm), dtype_name,
                               grid_order)
            if (ex.mode == mode and ex.block_r == br and ex.block_c == bc
                    and ex.block_v == block_v and ex.exec_shape == exec_shape):
                source = "analytic"
        except ValueError:
            pass
    return RearrangePlan(
        mode=mode,
        kernel=kernel,
        canonical_shape=canon.shape,
        canonical_perm=canon.perm,
        out_shape=out_shape,
        exec_shape=exec_shape,
        block_r=br,
        block_c=bc,
        grid_order=grid_order,
        bytes_moved=bytes_moved,
        roofline_s=bytes_moved / (HBM_GBPS * 1e9),
        block_v=block_v,
        plan_source=source,
    )


@functools.lru_cache(maxsize=4096)
def _plan_cached(
    shape: tuple[int, ...], dtype_name: str, perm: tuple[int, ...], grid_order: str
) -> RearrangePlan:
    return _build_plan(shape, dtype_name, perm, grid_order)


def _tile_candidates(
    plan: RearrangePlan, shape: tuple, dtype_name: str, grid_order: str
) -> list[tune.Candidate]:
    """Enumerate the tuner's search space around one routed plan: the
    plan's own tile is the seed (the analytic derivation when the request
    was affine-recognized, the heuristic otherwise) and only its ±1
    neighborhood is enumerated — plus, on the ``reorder_nd`` routes, both
    grid-walk orders.  Cost scores include the padded-block traffic and
    grid-step count so the model can separate candidates that move the
    same useful bytes at different granularity."""
    itemsize = jnp.dtype(dtype_name).itemsize
    n_elems = 1
    for s in shape:
        n_elems *= int(s)
    cands: list[tune.Candidate] = []

    def add(br: int, bc: int, go: str, padded_elems: int, steps: int) -> None:
        label = f"br{br}_bc{bc}_{go}"
        if any(c.label == label for c in cands):
            return
        cands.append(
            tune.Candidate(
                label=label,
                params=(("block_r", br), ("block_c", bc), ("grid_order", go)),
                cost_s=movement_cost_s(2 * padded_elems * itemsize, steps),
            )
        )

    if plan.mode == "transpose":
        b, r, c, v = plan.exec_shape
        if v > 1:
            bv = plan.block_v or plan_transpose_vec_tiles(r, c, v, dtype_name).block_v
            seed_v = VecTilePlan(plan.block_r, plan.block_c, bv,
                                 cdiv(r, plan.block_r), cdiv(c, plan.block_c),
                                 cdiv(v, bv))
            for vp in vec_tile_candidates(r, c, v, dtype_name, seed_v):
                padded = (
                    b
                    * (vp.grid_r * vp.block_r)
                    * (vp.grid_c * vp.block_c)
                    * (vp.grid_v * vp.block_v)
                )
                add(vp.block_r, vp.block_c, grid_order,
                    padded, b * vp.grid_r * vp.grid_c * vp.grid_v)
        else:
            seed = TilePlan(plan.block_r, plan.block_c,
                            cdiv(r, plan.block_r), cdiv(c, plan.block_c))
            for tp in transpose_tile_candidates(r, c, dtype_name, seed):
                padded = b * (tp.grid_r * tp.block_r) * (tp.grid_c * tp.block_c)
                add(tp.block_r, tp.block_c, grid_order,
                    padded, b * tp.grid_r * tp.grid_c)
    else:  # copy / reorder: reorder_nd kernel, both grid-walk orders
        enum = (
            copy_tile_candidates if plan.mode == "copy" else transpose_tile_candidates
        )
        r, c = _movement_plane(plan)
        batch = max(n_elems // max(r * c, 1), 1)
        seed = TilePlan(plan.block_r, plan.block_c,
                        cdiv(r, plan.block_r), cdiv(c, plan.block_c))
        for go in (grid_order, "in" if grid_order == "out" else "out"):
            for tp in enum(r, c, dtype_name, seed):
                padded = batch * (tp.grid_r * tp.block_r) * (tp.grid_c * tp.block_c)
                add(tp.block_r, tp.block_c, go, padded, batch * tp.grid_r * tp.grid_c)
    return cands


def _movement_plane(plan: RearrangePlan) -> tuple[int, int]:
    """The (rows, cols) plane the routed kernel tiles (canonical axes)."""
    canon = layout.canonicalize(plan.canonical_shape, plan.canonical_perm)
    return (
        plan.canonical_shape[canon.rows_axis],
        plan.canonical_shape[canon.cols_axis],
    )


def _runner_factory(shape: tuple, dtype_name: str, perm: tuple, grid_order: str):
    """Measured-mode runner: execute one candidate plan on a deterministic
    sample array (jitted, device-synced by the tuner's timing loop)."""

    def factory(cand: tune.Candidate):
        import jax

        from repro.kernels import ops  # lazy: ops imports this module

        d = cand.param_dict()
        plan = _build_plan(
            shape, dtype_name, perm, d["grid_order"],
            block_r=d["block_r"], block_c=d["block_c"],
        )
        x = tune.sample_array(shape, dtype_name)
        fn = jax.jit(lambda a: ops.apply_plan(a, plan))
        return lambda: fn(x)

    return factory


@functools.lru_cache(maxsize=4096)
def _plan_tuned_cached(
    shape: tuple[int, ...],
    dtype_name: str,
    perm: tuple[int, ...],
    grid_order: str,
    mode: str,
) -> RearrangePlan:
    base = _plan_cached(shape, dtype_name, perm, grid_order)
    if base.mode == "identity":
        return base  # nothing to tune: no data moves
    cands = _tile_candidates(base, shape, dtype_name, grid_order)
    choice = tune.select(
        "rearrange",
        f"shape={shape}|dtype={dtype_name}|perm={perm}|go={grid_order}",
        cands,
        _runner_factory(shape, dtype_name, perm, grid_order),
        mode=mode,
    )
    d = choice.param_dict()
    if (
        d["block_r"] == base.block_r
        and d["block_c"] == base.block_c
        and d["grid_order"] == base.grid_order
    ):
        return base  # seed won: tuned and untuned plans are the SAME object
    out = _build_plan(
        shape, dtype_name, perm, d["grid_order"],
        block_r=d["block_r"], block_c=d["block_c"],
    )
    return replace(out, plan_source="tuned")


def plan_rearrange(
    shape: Sequence[int],
    dtype,
    perm: Sequence[int],
    *,
    grid_order: str = "out",
    tuned: bool | None = None,
) -> RearrangePlan:
    """Plan (and cache) the movement for ``transpose(x, perm)``.

    ``tuned=None`` (default) resolves from ``REPRO_TUNE`` — off unless the
    variable opts in, so default plans are bit-identical to the pre-tuner
    engine.  ``tuned=True`` routes through the autotuner (DESIGN.md §11):
    the tile neighborhood is measured (TPU) or cost-scored (elsewhere) and
    the winner is cached with the same lru identity guarantees.
    """
    perm_t = tuple(int(p) for p in perm)
    if sorted(perm_t) != list(range(len(shape))):
        raise ValueError(f"bad perm {perm_t} for rank {len(shape)}")
    if grid_order not in ("in", "out"):
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    if tuned is None:
        tuned = tune.tune_default()
    key = (tuple(int(s) for s in shape), jnp.dtype(dtype).name, perm_t, grid_order)
    if not tuned:
        return _plan_cached(*key)
    return _plan_tuned_cached(*key, tune.resolve_mode())


# ---------------------------------------------------------------------------
# affine plans (DESIGN.md §14): requests arriving as an AffineMap — the new
# ops (bit_reversal, strided/diagonal reorder, seeded shuffle) and anything
# the recognizer lifts.  The tile comes from the closed-form derivation
# (`affine.derive`), so the plan source is `analytic` by construction; the
# tuner only *verifies* the seed against its ±1 neighborhood.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1024)
def _plan_affine_cached(
    amap: affine.AffineMap, dtype_name: str, grid_order: str
) -> RearrangePlan:
    itemsize = jnp.dtype(dtype_name).itemsize
    out_shape = tuple(amap.out_digits)
    n_out = amap.n_out
    if n_out == 0 or amap.n_in == 0:
        return RearrangePlan(
            mode="identity", kernel="noop",
            canonical_shape=amap.in_digits,
            canonical_perm=tuple(range(len(amap.in_digits))),
            out_shape=out_shape, exec_shape=None, block_r=1, block_c=1,
            grid_order=grid_order, bytes_moved=0, roofline_s=0.0,
            plan_source="analytic",
        )
    ex = affine.derive(amap, dtype_name, grid_order)
    m = ex.amap
    bytes_moved = 2 * n_out * itemsize
    if ex.mode == "transpose":
        kernel = (
            "transpose2d_batched_vec" if ex.block_v is not None
            else "transpose2d_batched"
        )
    else:
        kernel = {
            "identity": "noop", "copy": "reorder_nd",
            "reorder": "reorder_nd", "affine": "reorder_affine",
        }[ex.mode]
    return RearrangePlan(
        mode=ex.mode, kernel=kernel,
        canonical_shape=m.in_digits, canonical_perm=m.src,
        out_shape=out_shape, exec_shape=ex.exec_shape,
        block_r=ex.block_r, block_c=ex.block_c, grid_order=grid_order,
        bytes_moved=bytes_moved, roofline_s=bytes_moved / (HBM_GBPS * 1e9),
        block_v=ex.block_v, plan_source="analytic",
        amap=m if ex.mode == "affine" else None,
    )


def _affine_tile_candidates(
    base: RearrangePlan, dtype_name: str
) -> list[tune.Candidate]:
    """The verification neighborhood for an analytic plan: the derived seed
    ±1 step.  Permutation-class plans reuse the generic enumeration; the
    ``affine``-mode kernel searches its (jr, jc) plane, with the lane block
    pinned when the skewed lane digit is resident."""
    if base.mode != "affine":
        return _tile_candidates(
            base, base.canonical_shape, dtype_name, base.grid_order
        )
    itemsize = jnp.dtype(dtype_name).itemsize
    ex = affine.derive(base.amap, dtype_name, base.grid_order)
    R = base.amap.out_digits[ex.jr] if ex.jr is not None else 1
    C = base.amap.out_digits[ex.jc]
    batch = max(base.amap.n_out // max(R * C, 1), 1)
    seed = TilePlan(base.block_r, base.block_c,
                    cdiv(R, base.block_r), cdiv(C, base.block_c))
    enum = copy_tile_candidates if ex.resident_skew else transpose_tile_candidates
    cands: list[tune.Candidate] = []
    for tp in enum(R, C, dtype_name, seed):
        label = f"br{tp.block_r}_bc{tp.block_c}_{base.grid_order}"
        if any(c.label == label for c in cands):
            continue
        padded = batch * (tp.grid_r * tp.block_r) * (tp.grid_c * tp.block_c)
        cands.append(
            tune.Candidate(
                label=label,
                params=(("block_r", tp.block_r), ("block_c", tp.block_c),
                        ("grid_order", base.grid_order)),
                cost_s=movement_cost_s(
                    2 * padded * itemsize, batch * tp.grid_r * tp.grid_c
                ),
            )
        )
    return cands


def _affine_runner_factory(
    amap: affine.AffineMap, dtype_name: str, grid_order: str
):
    """Measured-mode runner for affine plans (mirrors `_runner_factory`)."""

    def factory(cand: tune.Candidate):
        import jax

        from repro.kernels import ops  # lazy: ops imports this module

        d = cand.param_dict()
        base = _plan_affine_cached(amap, dtype_name, d["grid_order"])
        plan = replace(base, block_r=d["block_r"], block_c=d["block_c"])
        x = tune.sample_array(base.canonical_shape, dtype_name)
        fn = jax.jit(lambda a: ops.apply_plan(a, plan))
        return lambda: fn(x)

    return factory


@functools.lru_cache(maxsize=1024)
def _plan_affine_tuned_cached(
    amap: affine.AffineMap, dtype_name: str, grid_order: str, mode: str
) -> RearrangePlan:
    base = _plan_affine_cached(amap, dtype_name, grid_order)
    if base.mode == "identity":
        return base  # nothing to tune: no data moves
    cands = _affine_tile_candidates(base, dtype_name)
    key = (
        f"amap={amap.in_digits}->{amap.out_digits}|src={amap.src}|"
        f"base={amap.base}|rot={amap.rot}|skew={amap.skew}{amap.skew_sign}|"
        f"dtype={dtype_name}|go={grid_order}"
    )
    choice = tune.select(
        "rearrange", key, cands,
        _affine_runner_factory(amap, dtype_name, grid_order), mode=mode,
    )
    d = choice.param_dict()
    if (
        d["block_r"] == base.block_r
        and d["block_c"] == base.block_c
        and d["grid_order"] == base.grid_order
    ):
        return base  # analytic seed verified: SAME object as the untuned plan
    return replace(
        base, block_r=d["block_r"], block_c=d["block_c"],
        grid_order=d["grid_order"], plan_source="tuned",
    )


def plan_affine(
    amap: affine.AffineMap,
    dtype,
    *,
    grid_order: str = "out",
    tuned: bool | None = None,
) -> RearrangePlan:
    """Plan (and cache) the movement for one :class:`~repro.core.affine.AffineMap`.

    The affine analogue of :func:`plan_rearrange`: the map is coalesced
    (``affine.merge_runs``), classified, and tiled in closed form by
    :func:`affine.derive` — permutation-class maps land on the existing
    kernel routes, anything with window bases / rotations / skew lands on
    the generalized ``reorder_affine`` kernel.  Raises ValueError when the
    map has no single-pass lowering (callers fall back to their oracle).
    ``tuned`` resolves like :func:`plan_rearrange`; because the seed is the
    derivation itself, tuning is a verification pass over its ±1
    neighborhood.
    """
    if not isinstance(amap, affine.AffineMap):
        raise TypeError(f"plan_affine wants an AffineMap, got {type(amap)}")
    if grid_order not in ("in", "out"):
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    if tuned is None:
        tuned = tune.tune_default()
    key = (amap, jnp.dtype(dtype).name, grid_order)
    if not tuned:
        return _plan_affine_cached(*key)
    return _plan_affine_tuned_cached(*key, tune.resolve_mode())


def plan_cache_info():
    """Expose the memo stats (tests / benchmarks)."""
    return _plan_cached.cache_info()


def affine_plan_cache_info():
    """Expose the affine-path memo stats (tests / benchmarks)."""
    return _plan_affine_cached.cache_info()


def tuned_plan_cache_info():
    """Expose the tuned-path memo stats (tests / benchmarks)."""
    return _plan_tuned_cached.cache_info()
