"""Gradient-correctness tier for the training hot path (DESIGN.md §13).

Three families:

* **flash backward** — the custom-VJP flash kernel (`kernels/flash.py`)
  against the naive `ref.py` attention oracle: ``jax.test_util.check_grads``
  (fp32 second-order, rev mode — fwd-mode AD is unsupported on custom_vjp),
  reference-VJP comparison for bf16, causal and non-causal, ragged
  sequence lengths, ``q_offset`` continuation, and the zero-size batch;
  plus jaxpr asserts that the backward lowers to pallas_calls without
  materializing the full ``(B, H, S, S)`` attention matrix (kernel VMEM
  tiles are 2-D ``(bq, bk)`` blocks — only the naive path stages the 4-D
  batched matrix).
* **grad accumulation** — ``make_train_step(accum_steps=k)`` matches
  ``accum_steps=1`` on the same effective batch to fp32-accumulator
  tolerance (the microbatch mean-of-means reassociates the reduction, so
  exact bit identity is not attainable; the bound here is ~100x tighter
  than any training-relevant signal), and raises a clear ``ValueError``
  when the batch is not divisible.
* **blockwise-parallel blocks** — chunked attention+FFN forward
  *bit-matches* the monolithic block (fp32) for every remat policy
  (masked KV chunks pass the online-softmax state through unchanged, so
  truncation is exact); gradients tolerance-match (query-chunking
  reassociates the dk/dv accumulation).  The Pallas kernel dispatch path
  (`REPRO_FLASH_KERNEL=1`) is exercised explicitly.  The same equivalence
  on the 8-device mesh lives in ``tests/test_dist_plan.py`` (the ``make
  test-dist`` launcher).
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro import configs
from repro.kernels import flash, ref
from repro.models import attention, common, mlp
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train import trainer

INTERP = jax.default_backend() != "tpu"
RNG = np.random.default_rng(11)


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def qkv(b, hq, hkv, sq, skv, d, dtype=jnp.float32):
    return (
        rand((b, hq, sq, d), dtype),
        rand((b, hkv, skv, d), dtype),
        rand((b, hkv, skv, d), dtype),
    )


def tree_maxdiff(a, b):
    return max(
        float(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)).max())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ---------------------------------------------------------------------------
# flash backward: check_grads + reference-VJP comparisons
# ---------------------------------------------------------------------------


class TestFlashBackward:
    @pytest.mark.parametrize("causal", [True, False])
    def test_check_grads_fp32_second_order(self, causal):
        """fp32 rectangular kernel: first+second order rev-mode derivatives
        match finite differences (ISSUE 7 acceptance)."""
        q, k, v = qkv(1, 4, 2, 24, 24, 8)

        def f(q, k, v):
            return flash.flash_attention(
                q, k, v, causal=causal, block_q=8, block_k=8, interpret=INTERP
            )

        check_grads(f, (q, k, v), order=2, modes=["rev"], atol=2e-2, rtol=2e-2)

    def test_check_grads_triangular_fp32_second_order(self):
        """The triangular (prefetch-table) kernel differentiates too."""
        q, k, v = qkv(1, 2, 2, 24, 24, 8)

        def f(q, k, v):
            return flash.flash_attention_triangular(
                q, k, v, block_q=8, block_k=8, interpret=INTERP
            )

        check_grads(f, (q, k, v), order=2, modes=["rev"], atol=2e-2, rtol=2e-2)

    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_ref_fp32(self, causal):
        """First-order VJP against the naive ref.py oracle, fp32."""
        q, k, v = qkv(2, 4, 2, 32, 32, 16)
        do = rand((2, 4, 32, 16))

        def fl(q, k, v):
            return flash.flash_attention(
                q, k, v, causal=causal, block_q=16, block_k=16, interpret=INTERP
            )

        def rf(q, k, v):
            return ref.attention(q, k, v, causal=causal)

        g1 = jax.vjp(fl, q, k, v)[1](do)
        g2 = jax.vjp(rf, q, k, v)[1](do)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3
            )

    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_ref_bf16(self, causal):
        """bf16 first-order reference-VJP comparison (finite differences
        are too noisy at bf16 resolution, so the oracle IS the check)."""
        q, k, v = qkv(1, 4, 2, 32, 32, 16, jnp.bfloat16)
        do = rand((1, 4, 32, 16), jnp.bfloat16)

        def fl(q, k, v):
            return flash.flash_attention(
                q, k, v, causal=causal, block_q=16, block_k=16, interpret=INTERP
            )

        def rf(q, k, v):
            return ref.attention(q, k, v, causal=causal)

        g1 = jax.vjp(fl, q, k, v)[1](do)
        g2 = jax.vjp(rf, q, k, v)[1](do)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=0.08, rtol=0.08,
            )

    def test_grads_ragged_and_offset(self):
        """Non-multiple-of-block shapes + q_offset continuation: the padded
        rows are cleaned inside the kernels, so grads match ref exactly on
        the valid region (and carry no NaN)."""
        q, k, v = qkv(1, 4, 2, 13, 29, 8)
        do = rand((1, 4, 13, 8))

        def fl(q, k, v):
            return flash.flash_attention(
                q, k, v, causal=True, q_offset=16, block_q=8, block_k=8,
                interpret=INTERP,
            )

        def rf(q, k, v):
            return ref.attention(q, k, v, causal=True, q_offset=16)

        g1 = jax.vjp(fl, q, k, v)[1](do)
        g2 = jax.vjp(rf, q, k, v)[1](do)
        for a, b in zip(g1, g2):
            assert not np.isnan(np.asarray(a)).any()
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3
            )

    def test_zero_size_batch(self):
        """b=0 flows through fwd and bwd without tracing errors or NaN."""
        q, k, v = qkv(0, 4, 2, 8, 8, 8)

        def loss(q, k, v):
            return flash.flash_attention(
                q, k, v, causal=True, block_q=8, block_k=8, interpret=INTERP
            ).sum()

        val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
        assert float(val) == 0.0
        assert grads[0].shape == (0, 4, 8, 8)
        assert grads[1].shape == (0, 2, 8, 8)

    def test_backward_lowers_to_pallas_no_sxs(self):
        """The grad jaxpr contains the three pallas_calls (fwd + dq sweep +
        dkv sweep) and never stages the batched (B, H, S, S) attention
        matrix — the hallmark of the naive path.  Kernel-internal VMEM
        tiles are 2-D (bq, bk) blocks smaller than S, so the 4-D shape
        pattern is a precise discriminator."""
        b, hq, s, d = 2, 4, 48, 8
        q, k, v = qkv(b, hq, 2, s, s, d)

        def loss(q, k, v):
            return flash.flash_attention(
                q, k, v, causal=True, block_q=16, block_k=16, interpret=INTERP
            ).sum()

        jx = str(jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v))
        assert len(re.findall(r"\bpallas_call\b", jx)) >= 3
        sxs = rf"f32\[{b},{hq},{s},{s}\]"
        assert not re.search(sxs, jx), "full attention matrix materialized"
        # the naive ref path DOES stage it — sanity-check the discriminator
        jx_ref = str(
            jax.make_jaxpr(
                jax.grad(lambda a, c, w: ref.attention(a, c, w).sum(),
                         argnums=(0, 1, 2))
            )(q, k, v)
        )
        assert re.search(sxs, jx_ref)

    def test_plan_flash_bwd_identity_and_describe(self):
        """Plan-engine contract: lru identity + human-readable describe."""
        p1 = flash.plan_flash_bwd(2, 4, 2, 256, 256, 64, jnp.float32)
        p2 = flash.plan_flash_bwd(2, 4, 2, 256, 256, 64, jnp.float32)
        assert p1 is p2
        assert p1.block_q == 256 and p1.block_k == 256
        assert "flash_bwd" in p1.describe()
        assert p1.bytes_moved == flash.bwd_dma_bytes(
            2, 4, 2, 256, 256, 64, 4, block_q=256, block_k=256
        )


# ---------------------------------------------------------------------------
# grad accumulation
# ---------------------------------------------------------------------------


def _smoke_cfg(**kw):
    return configs.get_config("qwen2-7b-smoke").with_(dtype="float32", **kw)


def _batch(cfg, b, s, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab),
    }


class TestGradAccum:
    def test_accum_matches_single_step(self):
        """accum_steps=2/4 reproduce the accum_steps=1 update on the same
        effective batch: loss to fp32-mean tolerance, updated params to
        ~1e-7 (fp32 accumulators; reduction reassociation only)."""
        cfg = _smoke_cfg()
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        oc = adamw.OptConfig(lr=1e-3)
        batch = _batch(cfg, 4, 32)
        p_ref, _, m_ref = trainer.make_train_step(cfg, oc, None)(params, opt, batch)
        for k in (2, 4):
            p_k, _, m_k = trainer.make_train_step(
                cfg, oc, None, accum_steps=k
            )(params, opt, batch)
            assert abs(float(m_ref["loss"]) - float(m_k["loss"])) < 5e-6
            assert tree_maxdiff(p_ref, p_k) < 1e-6

    def test_accum_indivisible_raises(self):
        """batch % accum_steps != 0 is a clear ValueError, not a reshape
        traceback."""
        cfg = _smoke_cfg()
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        step = trainer.make_train_step(
            cfg, adamw.OptConfig(), None, accum_steps=3
        )
        with pytest.raises(ValueError, match="divisible"):
            step(params, opt, _batch(cfg, 4, 16))


# ---------------------------------------------------------------------------
# blockwise-parallel blocks vs monolithic
# ---------------------------------------------------------------------------

POLICIES = list(common.REMAT_POLICIES)


class TestBlockwise:
    def test_remat_policy_resolution(self):
        """Name -> policy table, including the aliases and the error."""
        assert common.remat_policy(None) is None
        assert common.remat_policy("none") is None
        assert common.remat_policy("nothing_saveable") is None
        for name in POLICIES[1:]:
            assert callable(common.remat_policy(name))
        with pytest.raises(ValueError, match="unknown remat policy"):
            common.remat_policy("save_everything_twice")

    @pytest.mark.parametrize("policy", POLICIES)
    def test_loss_and_grads_match_monolithic_fp32(self, policy):
        """Forward loss bit-matches (masked-KV truncation is exact);
        gradients match to fp32 reassociation tolerance for every policy."""
        cfg = _smoke_cfg()
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, 2, 96)

        def lossg(c):
            return jax.value_and_grad(
                lambda p: tf.loss_fn(p, c, batch["tokens"], batch["labels"])
            )(params)

        l_mono, g_mono = lossg(cfg)
        l_bw, g_bw = lossg(
            cfg.with_(blockwise=True, blockwise_chunk=32, remat_policy=policy)
        )
        assert float(l_mono) == float(l_bw)  # bit-identical forward
        assert tree_maxdiff(g_mono, g_bw) < 1e-6

    def test_loss_matches_monolithic_bf16(self):
        """bf16 model: tolerance match (bf16 resolution)."""
        cfg = configs.get_config("qwen2-7b-smoke")
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, 2, 64)
        l1 = tf.loss_fn(params, cfg, batch["tokens"], batch["labels"])
        l2 = tf.loss_fn(
            params, cfg.with_(blockwise=True, blockwise_chunk=32),
            batch["tokens"], batch["labels"],
        )
        assert abs(float(l1) - float(l2)) < 1e-3

    def test_uneven_sequence_bitmatch(self):
        """Sequence not a multiple of the chunk: ragged tail chunk."""
        cfg = _smoke_cfg(loss_chunk=7)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, 2, 77)
        l1 = tf.loss_fn(params, cfg, batch["tokens"], batch["labels"])
        l2 = tf.loss_fn(
            params, cfg.with_(blockwise=True, blockwise_chunk=32),
            batch["tokens"], batch["labels"],
        )
        assert float(l1) == float(l2)

    def test_blockwise_attention_kernel_path_bitmatch(self, monkeypatch):
        """With the Pallas kernel dispatch forced on, the q-chunked wrapper
        (static per-chunk q_offset + aligned KV truncation) bit-matches the
        monolithic kernel call in fwd AND grad."""
        monkeypatch.setenv("REPRO_FLASH_KERNEL", "1")
        q, k, v = qkv(1, 4, 2, 64, 64, 16)
        mono = attention.flash_attention(q, k, v, causal=True, chunk=32)
        bw = attention.flash_attention_blockwise(
            q, k, v, causal=True, chunk=32, q_chunk=16
        )
        np.testing.assert_array_equal(np.asarray(mono), np.asarray(bw))
        g1 = jax.grad(
            lambda a: attention.flash_attention(a, k, v, causal=True, chunk=32).sum()
        )(q)
        g2 = jax.grad(
            lambda a: attention.flash_attention_blockwise(
                a, k, v, causal=True, chunk=32, q_chunk=16
            ).sum()
        )(q)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))

    def test_blockwise_grad_jaxpr_no_sxs(self, monkeypatch):
        """Under nothing_saveable + kernel dispatch, the blockwise grad
        jaxpr lowers to pallas_calls and stages no (B, H, S, S) f32
        matrix."""
        monkeypatch.setenv("REPRO_FLASH_KERNEL", "1")
        b, h, s, d = 1, 4, 64, 16
        q, k, v = qkv(b, h, 2, s, s, d)

        def loss(q):
            return attention.flash_attention_blockwise(
                q, k, v, causal=True, chunk=32, q_chunk=32, policy=None
            ).sum()

        jx = str(jax.make_jaxpr(jax.grad(loss))(q))
        assert re.search(r"\bpallas_call\b", jx)
        assert not re.search(rf"f32\[{b},{h},{s},{s}\]", jx)

    def test_mlp_blockwise_matches(self):
        """The seq-chunked FFN is pointwise over sequence; the chunked
        output shape changes XLA's GEMM tiling, so equality holds to
        last-ulp accumulation tolerance (measured ~2e-7 fp32), ragged
        tail chunk included."""
        cfg = _smoke_cfg()
        p = mlp.mlp_init(jax.random.PRNGKey(5), cfg)
        x = rand((2, 50, cfg.d_model))
        y1 = mlp.mlp_apply(p, cfg, x)
        y2 = mlp.mlp_apply_blockwise(p, cfg, x, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-6)
        g1 = jax.grad(lambda a: mlp.mlp_apply(p, cfg, a).sum())(x)
        g2 = jax.grad(lambda a: mlp.mlp_apply_blockwise(p, cfg, a, chunk=16).sum())(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=2e-6)

    def test_train_step_runs_blockwise(self):
        """make_train_step over the blockwise model: finite loss + grads
        flow (the full wiring: chunked blocks -> accumulation -> AdamW)."""
        cfg = _smoke_cfg(blockwise=True, blockwise_chunk=32)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        step = trainer.make_train_step(
            cfg, adamw.OptConfig(lr=1e-3), None, accum_steps=2
        )
        p2, _, metrics = step(params, opt, _batch(cfg, 4, 64))
        assert np.isfinite(float(metrics["loss"]))
        assert tree_maxdiff(params, p2) > 0  # params actually moved
