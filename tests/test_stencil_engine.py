"""Stencil plan engine: fused multi-stage pipelines via temporal blocking.

Covers the acceptance surface of the stencil-engine PR (DESIGN.md §9):
* oracle equivalence of a fused ``repeat(k)`` program vs k sequential
  reference sweeps for every boundary mode, radii 1-2, fp32/bf16,
  non-multiple-of-panel heights, and zero-size inputs;
* a fused program (k >= 4) lowers to exactly ONE pallas_call;
* the plan cache returns the identical plan object on repeated calls;
* ``then`` composition, trace-time functor stages, and aux (source-term)
  programs match their sequential references;
* kernel-level panel/boundary corner cases (forced small panels, periodic
  mod-index-map wrap, halo deeper than the grid).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stencil as st
from repro.kernels import ops, ref
from repro.kernels import stencil2d as st_k

RNG = np.random.default_rng(11)

BOUNDARIES = ["zero", "nearest", "reflect", "periodic"]


def rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def sweeps(x, stencil: st.Stencil, k: int, boundary: str):
    """k sequential full-grid reference sweeps — the fused oracle."""
    for _ in range(k):
        x = ref.stencil2d(x, stencil.offsets, stencil.weights, boundary=boundary)
    return x


def n_pallas_calls(fn, *args) -> int:
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call[")


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# fused repeat(k) vs k sequential sweeps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("radius", [1, 2])
@pytest.mark.parametrize("boundary", BOUNDARIES)
def test_repeat_matches_sequential_sweeps(boundary, radius, dtype, pallas_interpret):
    """H=67 is a non-multiple of the default 64-row panel (partial final
    panel); radius 2 uses the 9-point fd_laplacian(2)."""
    s = st.fd_laplacian(radius).scale(0.1)
    x = rand((67, 33), dtype)
    got = s.repeat(4)(x, boundary=boundary)
    want = sweeps(x, s, 4, boundary)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("shape", [(8, 128), (64, 64), (70, 17), (3, 9)])
def test_repeat_shapes_zero_boundary(shape, pallas_interpret):
    """Sub-panel, exact, ragged, and halo-deeper-than-grid heights."""
    s = st.fd_laplacian(1).scale(0.2)
    x = rand(shape)
    got = s.repeat(5)(x)
    want = sweeps(x, s, 5, "zero")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(0, 16), (16, 0), (0, 0)])
def test_zero_size_inputs(shape, pallas_interpret):
    prog = st.fd_laplacian(1).repeat(4)
    out = prog(jnp.zeros(shape, jnp.float32))
    assert out.shape == shape


# ---------------------------------------------------------------------------
# single fused pallas_call + plan cache
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundary", BOUNDARIES)
def test_repeat4_single_pallas_call(boundary, pallas_interpret):
    prog = st.fd_laplacian(1).scale(0.1).repeat(4)
    x = rand((64, 40))
    assert n_pallas_calls(lambda t: prog(t, boundary=boundary), x) == 1
    plan = prog.compile(x.shape, x.dtype, boundary=boundary)
    assert plan.mode == "fused" and plan.kernel == "stencil2d_pipeline"


def test_deep_repeat_single_pallas_call(pallas_interpret):
    """k=8 with radius 1: a 8-row halo, still one kernel."""
    prog = st.fd_laplacian(1).scale(0.1).repeat(8)
    x = rand((128, 32))
    assert n_pallas_calls(prog, x) == 1


def test_plan_cache_returns_identical_object():
    a = st.fd_laplacian(1).repeat(6).compile((256, 128), jnp.float32)
    b = st.fd_laplacian(1).repeat(6).compile((256, 128), jnp.float32)
    assert a is b  # distinct program objects, same descriptors -> same plan
    c = st.fd_laplacian(1).repeat(6).compile((256, 128), jnp.float32, boundary="reflect")
    assert c is not a and c.boundary == "reflect"
    d = st.fd_laplacian(1).repeat(6).compile((256, 128), jnp.bfloat16)
    assert d is not a


def test_plan_cost_model_prefers_fusion():
    plan = st.fd_laplacian(1).repeat(8).compile((4096, 4096), jnp.float32)
    assert plan.mode == "fused"
    assert plan.bytes_per_sweep_path > 4 * plan.bytes_moved  # ~8x ideal
    assert plan.grid == 4096 // plan.block_rows
    assert "fused" in plan.describe()


def test_plan_reference_fallback_on_tiny_columns():
    """reflect columns need W >= radius+1; the planner must route the
    program to the reference path instead of failing."""
    plan = st.fd_laplacian(2).repeat(2).compile((64, 2), jnp.float32, boundary="reflect")
    assert plan.mode == "reference"
    x = rand((64, 2))
    prog = st.fd_laplacian(2).repeat(2)
    got = prog(x, boundary="reflect")
    want = sweeps(x, st.fd_laplacian(2), 2, "reflect")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# composition: then / functor stages / aux programs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundary", BOUNDARIES)
def test_then_composition_mixed_radii(boundary, pallas_interpret):
    blur, lap = st.box_blur(2), st.fd_laplacian(1)
    prog = blur.then(lap).repeat(2)  # radii 2,1,2,1 -> halo 6
    assert prog.n_stages == 4 and prog.total_radius == 6
    x = rand((48, 24))
    got = prog(x, boundary=boundary)
    want = x
    for s in [blur, lap, blur, lap]:
        want = ref.stencil2d(want, s.offsets, s.weights, boundary=boundary)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def _shift_max(shift):
    return jnp.maximum(jnp.maximum(shift(0, -1), shift(0, 1)), shift(0, 0))


def test_functor_stage_nonlinear_pipeline(pallas_interpret):
    """Non-linear trace-time functor stages compose with linear ones."""
    prog = st.functor_stage(_shift_max, 1).then(st.box_blur(1)).repeat(2)
    x = rand((40, 30))
    got = prog(x)
    want = x
    for _ in range(2):
        want = ref.stencil2d_functor(want, _shift_max, 1)
        want = ref.stencil2d(want, st.box_blur(1).offsets, st.box_blur(1).weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert n_pallas_calls(prog, x) == 1


def _jacobi_src(shift, src):
    return 0.25 * (shift(1, 0) + shift(-1, 0) + shift(0, 1) + shift(0, -1)) + src()


def test_aux_source_term_program(pallas_interpret):
    """Jacobi iteration with a right-hand side rides as the aux operand
    (the CFD cavity Poisson solve, examples/cfd_cavity.py)."""
    prog = st.functor_stage(_jacobi_src, 1).repeat(6)
    x, b = rand((67, 31)), rand((67, 31))
    got = prog(x, aux=b)
    want = x
    for _ in range(6):
        want = ref.stencil2d_functor(want, _jacobi_src, 1, aux=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert n_pallas_calls(lambda t, a: prog(t, aux=a), x, b) == 1


# ---------------------------------------------------------------------------
# kernel-level panel / boundary corner cases
# ---------------------------------------------------------------------------


def _lap(shift, *_):
    return shift(-1, 0) + shift(1, 0) + shift(0, -1) + shift(0, 1) - 4.0 * shift(0, 0)


@pytest.mark.parametrize("boundary", ["zero", "nearest", "reflect"])
def test_forced_small_panels_partial_final(boundary):
    """block_rows=16 over H=50: four panels, ragged final panel."""
    x = rand((50, 21))
    stages = ((_lap, 1),) * 4
    got = st_k.stencil2d_pipeline(
        x, stages, boundary=boundary, block_rows=16, interpret=True
    )
    want = ref.stencil_pipeline(x, stages, boundary=boundary)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_periodic_multi_panel_mod_index_maps():
    """H=48 with block_rows=16 exercises the wrap-around halo blocks."""
    x = rand((48, 19))
    stages = ((_lap, 1),) * 4
    got = st_k.stencil2d_pipeline(
        x, stages, boundary="periodic", block_rows=16, interpret=True
    )
    want = ref.stencil_pipeline(x, stages, boundary="periodic")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_periodic_halo_deeper_than_grid():
    """R=5 > H=3: the wrap halo must tile the grid multiple times."""
    x = rand((3, 9))
    stages = ((_lap, 1),) * 5
    got = st_k.stencil2d_pipeline(x, stages, boundary="periodic", interpret=True)
    want = ref.stencil_pipeline(x, stages, boundary="periodic")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_single_sweep_boundary_family_dispatch(pallas_interpret):
    """ops.stencil2d now routes every boundary mode through the kernel."""
    s = st.fd_laplacian(1)
    x = rand((33, 20))
    for boundary in BOUNDARIES:
        got = ops.stencil2d(x, s.offsets, s.weights, boundary=boundary)
        want = ref.stencil2d(x, s.offsets, s.weights, boundary=boundary)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_program_rejects_bad_inputs():
    prog = st.fd_laplacian(1).repeat(2)
    with pytest.raises(ValueError, match="2-D"):
        prog(jnp.zeros((4, 4, 4), jnp.float32))
    with pytest.raises(ValueError, match="k >= 1"):
        prog.repeat(0)
    with pytest.raises(ValueError, match="boundary"):
        prog.compile((32, 32), jnp.float32, boundary="sideways")


def test_kernel_rejects_bad_block_rows():
    x = rand((64, 32))
    with pytest.raises(ValueError, match="block_rows"):
        st_k.stencil2d_pipeline(
            x, ((_lap, 1),) * 4, block_rows=2, interpret=True
        )


def test_shift_beyond_stage_radius_raises():
    x = rand((32, 32))

    def too_far(shift):
        return shift(2, 0)

    with pytest.raises(ValueError, match="exceeds stage radius"):
        st_k.stencil2d_pipeline(x, ((too_far, 1),), interpret=True)
