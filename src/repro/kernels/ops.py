"""Dispatch layer: one public op per kernel, Pallas on TPU / oracle elsewhere.

Dispatch rules
--------------
* On TPU the Pallas kernels own the fast path.
* On CPU/GPU the jnp oracles (``ref.py``) are the dispatch target — XLA
  fuses them competitively, and (critically for this container) the
  multi-pod **dry-run compiles the XLA path**, keeping HLO clean for the
  roofline analysis.
* ``REPRO_PALLAS_INTERPRET=1`` forces every op through the Pallas kernel in
  interpret mode — this is how the test suite validates kernel semantics
  on CPU.
* Kernels have alignment preconditions (lane divisibility etc.).  When an
  input violates them, the op silently falls back to the oracle — the
  library never fails on an odd shape, it just loses the fast path (same
  contract as the paper's library).
"""

from __future__ import annotations

import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import affine
from repro.core.index_plan import IndexPlan, plan_index_op
from repro.core.plan import RearrangePlan, plan_affine, plan_rearrange
from repro.kernels import (
    copy as copy_k,
    gather_scatter as gs_k,
    interlace as il_k,
    permute3d as p3_k,
    ref,
    reorder_nd as rnd_k,
    stencil2d as st_k,
)

Array = jax.Array


def _platform() -> str:
    return jax.devices()[0].platform


def use_pallas() -> bool:
    """True when dispatch should target the Pallas kernels (TPU, or any
    platform under ``REPRO_PALLAS_INTERPRET=1``)."""
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return True
    if os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1":
        return False
    return _platform() == "tpu"


def _interpret() -> bool:
    return _platform() != "tpu"


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------


def copy(x: Array) -> Array:
    """Materialized device copy (paper §III-A read/write kernel)."""
    if use_pallas():
        try:
            return copy_k.copy(x, interpret=_interpret())
        except ValueError:
            pass
    return ref.copy(x)


def copy_range(x: Array, start, size: int) -> Array:
    """Ranged access: copy ``x[start:start+size]`` along axis 0."""
    if use_pallas() and x.ndim == 2:
        return copy_k.copy_range(x, start, size, interpret=_interpret())
    return ref.copy_range(x, start, size)


def apply_index_plan(
    x: Array, idx: Array, plan: IndexPlan, gates: Array | None = None
) -> Array:
    """Execute an :class:`IndexPlan` on ``x`` with the blocked kernels.

    Every route is at most ONE kernel invocation over HBM:

      noop           -> zeros (empty table / empty rows), no kernel
      gather         -> blocked masked gather (run-detected block copies)
                        — or the seed rowwise kernel when the tuner
                        selected that engine (unmasked gathers only)
      scatter        -> the same gather through the inverted index table
                        (an int32 table op; unmapped rows stay zero)
      gather_combine -> fused gather + weighted combine (needs ``gates``)
      ragged_rows    -> the masked gather route above; -1 sentinels zero
                        the tail rows (the serving engine's ragged-prefill
                        unpack, DESIGN.md §12)
    """
    interp = _interpret()
    if plan.mode == "noop":
        return jnp.zeros((plan.n_out, x.shape[1]), x.dtype)
    if plan.mode == "rowwise":
        return gs_k.gather_rows(x, idx, interpret=interp)
    if plan.semantics == "scatter":
        inv = jnp.full((plan.n_out,), -1, jnp.int32).at[idx].set(
            jnp.arange(plan.n_src, dtype=jnp.int32), mode="drop"
        )
        return gs_k.gather_rows_blocked(
            x, inv, block_r=plan.block_rows, interpret=interp
        )
    if plan.semantics == "gather_combine":
        if gates is None:
            raise ValueError("gather_combine plans need the gates operand")
        return gs_k.gather_combine_blocked(
            x, idx, gates, block_t=plan.block_rows, interpret=interp
        )
    return gs_k.gather_rows_blocked(x, idx, block_r=plan.block_rows, interpret=interp)


def gather_rows(x: Array, idx: Array, *, masked: bool = False, engine: str = "plan") -> Array:
    """Index-set access: rows of ``x`` (axis 0) selected by ``idx``.

    ``masked=True`` enables sentinel semantics (``idx[i] < 0`` -> zero
    row).  ``engine="plan"`` (default) routes through the IndexPlan engine
    (blocked kernel, `core/index_plan.py`); ``engine="rowwise"`` keeps the
    seed one-row-per-grid-step kernel (benchmark baseline, unmasked only).
    """
    if engine not in ("plan", "rowwise"):
        raise ValueError(f"unknown gather_rows engine {engine!r}")
    if engine == "rowwise" and masked:
        raise ValueError("the rowwise engine has no sentinel masking")
    if use_pallas() and x.ndim == 2:
        if engine == "rowwise":
            return gs_k.gather_rows(x, idx, interpret=_interpret())
        plan = plan_index_op(x.shape, x.dtype, idx.shape[0], "gather", masked=masked)
        return apply_index_plan(x, idx, plan)
    if masked:
        return ref.gather_rows_masked(x, idx)
    return ref.gather_rows(x, idx)


def scatter_rows(x: Array, idx: Array, num_out: int | None = None) -> Array:
    """Injective row scatter: ``out[idx[i], :] = x[i, :]``.

    Contract (explicit — the seed version fell back silently):

    * ``idx`` must be injective into ``[0, num_out)``.  Duplicate targets
      leave the duplicated row unspecified (this cannot be validated
      eagerly on traced values); out-of-range targets are dropped.
    * ``num_out`` defaults to ``x.shape[0]`` (permutation scatter).
      ``num_out > x.shape[0]`` is the capacity-scatter case (rows nothing
      maps to — dropped slots — are zero-filled); it routes to the masked
      blocked kernel through the inverted table, the same fast path as the
      permutation case.
    * ``num_out < x.shape[0]`` cannot be injective: raises eagerly.
    * Non-2-D ``x`` has no Pallas fast path and dispatches to the oracle.
    """
    if idx.ndim != 1 or idx.shape[0] != x.shape[0]:
        raise ValueError(
            f"scatter_rows wants 1-D idx over x rows, got {x.shape}, {idx.shape}"
        )
    if num_out is not None and num_out < x.shape[0]:
        raise ValueError(
            f"scatter_rows num_out={num_out} < {x.shape[0]} rows cannot be injective"
        )
    n_out = x.shape[0] if num_out is None else num_out
    if use_pallas() and x.ndim == 2:
        plan = plan_index_op(x.shape, x.dtype, n_out, "scatter", masked=True)
        return apply_index_plan(x, idx, plan)
    return ref.scatter_rows(x, idx, num_out)


def gather_combine(src: Array, back: Array, gates: Array) -> Array:
    """Fused gather + weighted combine (the MoE combine primitive):
    ``out[t] = sum_k gates[t, k] * src[back[t, k]]``, with negative
    ``back`` entries contributing zero.  ONE `pallas_call` on the Pallas
    path (no (T*k, C) gathered intermediate in HBM)."""
    if back.ndim != 2 or gates.shape != back.shape:
        raise ValueError(
            f"gather_combine wants matching (T, k) back/gates, got "
            f"{back.shape}, {gates.shape}"
        )
    if use_pallas() and src.ndim == 2:
        plan = plan_index_op(
            src.shape, src.dtype, back.shape[0], "gather_combine",
            masked=True, top_k=back.shape[1],
        )
        return apply_index_plan(src, back, plan, gates=gates)
    return ref.gather_combine(src, back, gates)


def transpose2d_batched(x: Array, *, diagonal: bool = False) -> Array:
    """(B, R, C) -> (B, C, R) batched 2-D transpose (optionally with the
    paper's diagonalized block walk, DESIGN.md §8)."""
    if use_pallas():
        return p3_k.transpose2d_batched(x, diagonal=diagonal, interpret=_interpret())
    return ref.transpose2d_batched(x)


def apply_plan(x: Array, plan: RearrangePlan) -> Array:
    """Execute a :class:`RearrangePlan` on ``x`` with the Pallas kernels.

    Reshapes to/from the canonical form are metadata-only (adjacent-axis
    merges of a contiguous array), so every route is at most ONE kernel
    invocation over HBM:

      identity  -> pure reshape, zero data movement
      transpose -> batched 2-D transpose (scalar or V-deep elements)
      copy      -> reorder_nd in row-gather mode on the collapsed form
      reorder   -> generic reorder_nd on the collapsed form
      affine    -> generalized reorder_affine driven by the plan's AffineMap
    """
    interp = _interpret()
    if plan.mode == "identity":
        return x.reshape(plan.out_shape)
    if plan.mode == "affine":
        y = rnd_k.reorder_affine(
            x.reshape(plan.canonical_shape),
            plan.amap,
            block_r=plan.block_r,
            block_c=plan.block_c,
            grid_order=plan.grid_order,
            interpret=interp,
        )
        return y.reshape(plan.out_shape)
    if plan.mode == "transpose":
        b, r, c, v = plan.exec_shape
        if v > 1:
            y = p3_k.transpose2d_batched_vec(
                x.reshape(b, r, c, v),
                block_r=plan.block_r,
                block_c=plan.block_c,
                interpret=interp,
                **({"block_v": plan.block_v} if plan.block_v else {}),
            )
        else:
            y = p3_k.transpose2d_batched(
                x.reshape(b, r, c),
                block_r=plan.block_r,
                block_c=plan.block_c,
                interpret=interp,
            )
        return y.reshape(plan.out_shape)
    y = rnd_k.permute_nd(
        x.reshape(plan.canonical_shape),
        plan.canonical_perm,
        block_r=plan.block_r,
        block_c=plan.block_c,
        grid_order=plan.grid_order,
        interpret=interp,
    )
    return y.reshape(plan.out_shape)


def permute(x: Array, perm: Sequence[int], *, grid_order: str = "out") -> Array:
    """N-D transpose through the plan engine: collapse -> route -> cached
    plan -> at most ONE kernel pass (DESIGN.md §3)."""
    perm = tuple(int(p) for p in perm)
    if use_pallas():
        plan = plan_rearrange(x.shape, x.dtype, perm, grid_order=grid_order)
        return apply_plan(x, plan)
    return ref.permute(x, perm)


def _apply_affine(x: Array, amap: affine.AffineMap, out_shape) -> Array:
    """Shared affine-op dispatch: plan the map (analytic source), execute it
    as ONE kernel pass, and reshape to the user-facing ``out_shape``."""
    plan = plan_affine(amap, x.dtype)
    return apply_plan(x, plan).reshape(out_shape)


def bit_reversal(x: Array, *, axis: int = 0) -> Array:
    """Bit-reversal reorder along ``axis`` (FFT layouts, paper's reorder
    class): element ``i`` moves to bit-reversed index.  Affine route: the
    axis is digit-split into base-2 digits whose order is reversed — a
    clean digit permutation, ONE pallas_call, no index table."""
    axis = axis % max(x.ndim, 1)
    if use_pallas() and x.size:
        try:
            amap = affine.bit_reversal_map(x.shape, axis=axis)
            return _apply_affine(x, amap, x.shape)
        except ValueError:
            pass  # non-power-of-2 axis or unlowerable: oracle fallback
    return ref.bit_reversal(x, axis=axis)


def strided_gather(x: Array, stride: int, *, phase: int = 0, axis: int = 0) -> Array:
    """Strided window gather ``x[..., phase::stride, ...]`` along ``axis``.

    When ``stride`` divides the axis (and ``phase < stride``) this lowers
    through the affine planner: the axis digit-splits into
    ``(n // stride, stride)`` with the stride digit pinned at ``phase`` —
    a windowed affine map, ONE pallas_call, no materialized slice."""
    if stride <= 0:
        raise ValueError(f"stride must be positive, got {stride}")
    axis = axis % max(x.ndim, 1)
    if use_pallas() and x.size:
        try:
            amap = affine.strided_map(x.shape, axis=axis, stride=stride, phase=phase)
            out_shape = (
                x.shape[:axis] + (x.shape[axis] // stride,) + x.shape[axis + 1:]
            )
            return _apply_affine(x, amap, out_shape)
        except ValueError:
            pass  # stride/phase not digit-splittable: oracle fallback
    return ref.strided_gather(x, stride, phase=phase, axis=axis)


def diagonal_reorder(x: Array) -> Array:
    """Skewed-diagonal reorder ``out[..., i, j] = x[..., i, (i + j) % C]``
    (the paper's diagonal block walk applied to the data).  The affine
    lowering keeps the lane digit resident and applies the per-row modular
    shift in-register — ONE pallas_call, no gather table."""
    if x.ndim < 2:
        raise ValueError("diagonal_reorder wants rank >= 2")
    if use_pallas() and x.size:
        try:
            return _apply_affine(x, affine.diagonal_map(x.shape), x.shape)
        except ValueError:
            pass
    return ref.diagonal_reorder(x)


def shuffle(x: Array, seed: int = 0) -> Array:
    """Table-free seeded row shuffle (axis 0) — the epoch-shuffling
    primitive (ROADMAP item 3; bijective index functions per Mitchell et
    al., arXiv:2106.06161).  The seed draws a mixed-radix digit permutation
    plus per-digit rotations over the row index space: a bijection the
    affine planner lowers as ONE pallas_call with the row map evaluated in
    the scalar core — no O(n) index table in HBM.  The same seed always
    yields the same permutation; the oracle path materializes it as a
    gather table instead."""
    if use_pallas() and x.size and x.ndim >= 1 and x.shape[0] > 1:
        try:
            amap = affine.shuffle_map(x.shape[0], payload=x.shape[1:], seed=seed)
            return _apply_affine(x, amap, x.shape)
        except ValueError:
            pass
    return ref.shuffle(x, seed=seed)


def reorder_nm(
    x: Array,
    perm: Sequence[int],
    base: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
) -> Array:
    """N->M reorder: window select + permute + squeeze (paper §III-B)."""
    if base is None and sizes is None and len(perm) == x.ndim:
        return permute(x, perm)
    nd = x.ndim
    base_l = [0] * nd if base is None else list(base)
    sizes_l = list(x.shape) if sizes is None else list(sizes)
    kept = [int(p) for p in perm]
    kept_set = set(kept)
    for ax in range(nd):
        if ax not in kept_set and sizes_l[ax] != 1:
            raise ValueError(
                f"axis {ax} dropped by perm {perm} must have window size 1, "
                f"got {sizes_l[ax]}"
            )
    full_perm = kept + [ax for ax in range(nd) if ax not in kept_set]
    out_shape = tuple(sizes_l[ax] for ax in kept)
    static_base = all(isinstance(b, (int, np.integer)) for b in base_l)
    if use_pallas() and static_base:
        # fused one-pass form: the window base rides in the kernel's
        # index_map offsets, no materialized slice (DESIGN.md §6).  The base
        # is clamped like dynamic_slice so both paths agree on semantics.
        base_c = tuple(
            min(max(int(b), 0), x.shape[k] - int(sizes_l[k]))
            for k, b in enumerate(base_l)
        )
        try:
            moved = rnd_k.reorder_window(
                x,
                tuple(full_perm),
                base_c,
                tuple(int(s) for s in sizes_l),
                interpret=_interpret(),
            )
        except ValueError:
            pass  # base too misaligned for fused blocks: two-pass fallback
        else:
            return moved.reshape(out_shape)
    # runtime (traced) or misaligned base: slice, then permute via kernel
    window = jax.lax.dynamic_slice(x, base_l, sizes_l)
    moved = permute(window, full_perm) if use_pallas() else ref.permute(window, full_perm)
    return moved.reshape(out_shape)


def interlace(arrays: Sequence[Array]) -> Array:
    """Interleave n same-shape arrays along the last axis.  N-D inputs are
    flattened (a metadata reshape) so the whole op is one kernel pass."""
    arrays = list(arrays)
    same = arrays and arrays[0].ndim >= 1 and all(
        a.shape == arrays[0].shape and a.dtype == arrays[0].dtype for a in arrays
    )
    if use_pallas() and same:
        lead, last = arrays[0].shape[:-1], arrays[0].shape[-1]
        flat = tuple(a.reshape(-1) for a in arrays)
        try:
            out = il_k.interlace(flat, interpret=_interpret())
        except ValueError:
            return ref.interlace(arrays)
        return out.reshape(*lead, last * len(arrays))
    return ref.interlace(arrays)  # mismatched inputs raise in the oracle


def deinterlace(x: Array, n: int) -> list[Array]:
    """Inverse of :func:`interlace` along the last axis (N-D supported)."""
    if use_pallas() and x.ndim >= 1 and x.shape[-1] % n == 0:
        lead, last = x.shape[:-1], x.shape[-1]
        try:
            outs = il_k.deinterlace(x.reshape(-1), n, interpret=_interpret())
        except ValueError:
            return ref.deinterlace(x, n)
        return [o.reshape(*lead, last // n) for o in outs]
    return ref.deinterlace(x, n)


def stencil2d(
    x: Array,
    offsets,
    weights,
    *,
    boundary: str = "zero",
) -> Array:
    """Single weighted-sum stencil sweep (any of the four boundary modes)."""
    if use_pallas() and boundary in st_k.BOUNDARIES and x.ndim == 2 and x.size:
        try:
            return st_k.stencil2d(
                x, offsets, weights, boundary=boundary, interpret=_interpret()
            )
        except ValueError:
            pass  # no fused configuration for this shape: oracle fallback
    return ref.stencil2d(x, offsets, weights, boundary=boundary)


def stencil2d_functor(
    x: Array,
    functor: Callable,
    radius: int,
    *,
    boundary: str = "zero",
) -> Array:
    """Single generic-functor stencil sweep (trace-time specialization)."""
    if use_pallas() and boundary in st_k.BOUNDARIES and x.ndim == 2 and x.size:
        try:
            return st_k.stencil2d_functor(
                x, functor, radius, boundary=boundary, interpret=_interpret()
            )
        except ValueError:
            pass
    return ref.stencil2d_functor(x, functor, radius, boundary=boundary)


def stencil_program(
    x: Array,
    stages,
    *,
    boundary: str = "zero",
    block_rows: int | None = None,
    aux: Array | None = None,
    fused: bool = True,
    window: tuple | None = None,
) -> Array:
    """Execute a compiled stencil program (tuple of (functor, radius)
    stages — see ``core.stencil.StencilPlan.stages_exec``).

    Fused temporal-blocking kernel on the Pallas path; per-sweep oracle
    sweeps otherwise (or when the planner routed the program to the
    reference path, ``fused=False``).

    ``window=(row0, global_rows)`` runs the program in global-row-window
    mode (§10 halo exchange): ``x`` is a halo-extended shard whose row 0
    sits at global row ``row0`` (may be traced) of a ``global_rows``-row
    grid.  Boundary conditions then fire at the true grid edges and the
    caller crops the contaminated apron.  ``aux`` is a single-device-only
    feature and cannot be combined with ``window``.
    """
    if window is not None and aux is not None:
        raise ValueError("window mode does not support aux operands")
    row0, global_rows = (None, None) if window is None else window
    if fused and use_pallas() and x.size:
        try:
            return st_k.stencil2d_pipeline(
                x,
                stages,
                boundary=boundary,
                aux=aux,
                block_rows=block_rows,
                row0=row0,
                global_rows=global_rows,
                halo_resident=window is not None,
                interpret=_interpret(),
            )
        except ValueError:
            pass  # shape constraints changed underfoot: oracle fallback
    if window is not None:
        return ref.stencil_pipeline_window(
            x, stages, boundary=boundary, row0=row0, global_rows=global_rows
        )
    return ref.stencil_pipeline(x, stages, boundary=boundary, aux=aux)
