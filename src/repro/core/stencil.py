"""Generic stencil API (paper §III-D): stencils as first-class objects.

The paper ships the stencil as a C++ functor compiled into the kernel; we
ship it as a trace-time Python functor (or an (offsets, weights) table)
compiled into the Pallas kernel.  ``Stencil`` objects compose: scale, add,
and the standard finite-difference families are provided.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

Array = jax.Array


@dataclass(frozen=True)
class Stencil:
    """A linear stencil: out[p] = sum_k weights[k] * in[p + offsets[k]]."""

    offsets: tuple[tuple[int, int], ...]
    weights: tuple[float, ...]

    @property
    def radius(self) -> int:
        return max(max(abs(dy), abs(dx)) for dy, dx in self.offsets)

    def __call__(self, x: Array, *, boundary: str = "zero") -> Array:
        return ops.stencil2d(x, self.offsets, self.weights, boundary=boundary)

    def scale(self, a: float) -> "Stencil":
        return Stencil(self.offsets, tuple(a * w for w in self.weights))

    def __add__(self, other: "Stencil") -> "Stencil":
        table: dict[tuple[int, int], float] = {}
        for off, w in zip(self.offsets, self.weights):
            table[off] = table.get(off, 0.0) + w
        for off, w in zip(other.offsets, other.weights):
            table[off] = table.get(off, 0.0) + w
        offs = tuple(sorted(table))
        return Stencil(offs, tuple(table[o] for o in offs))


def fd_laplacian(order: int) -> Stencil:
    """2-D Laplacian, central differences of accuracy 2*order (paper Fig. 2
    orders I..IV)."""
    offs, wts = ref.fd_stencil_offsets(order)
    return Stencil(tuple(offs), tuple(wts))


def box_blur(radius: int = 1) -> Stencil:
    """(2r+1)^2 box smoothing filter (the paper's image-filter example)."""
    offs = tuple(
        (dy, dx)
        for dy in range(-radius, radius + 1)
        for dx in range(-radius, radius + 1)
    )
    w = 1.0 / len(offs)
    return Stencil(offs, (w,) * len(offs))


def apply_functor(
    x: Array, functor: Callable, radius: int, *, boundary: str = "zero"
) -> Array:
    """Arbitrary (possibly non-linear) stencil functor — see
    ``repro.kernels.stencil2d.stencil2d_functor``."""
    return ops.stencil2d_functor(x, functor, radius, boundary=boundary)


def conv1d_depthwise(x: Array, kernel: Array) -> Array:
    """Causal depthwise temporal conv over (B, S, D) with kernel (K, D) —
    the RG-LRU / recurrentgemma temporal-conv building block, expressed as
    a 1-D stencil (a degenerate §III-D stencil: all offsets (dy, 0)).

    out[b, s, d] = sum_k kernel[k, d] * x[b, s - (K-1) + k, d]
    """
    k = kernel.shape[0]
    pads = [(0, 0)] * x.ndim
    pads[-2] = (k - 1, 0)
    xp = jnp.pad(x, pads)
    out = jnp.zeros_like(x)
    s = x.shape[-2]
    for i in range(k):
        out = out + kernel[i] * jax.lax.dynamic_slice_in_dim(xp, i, s, axis=-2)
    return out
