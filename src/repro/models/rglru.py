"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block = norm -> {gate branch: linear+GeLU} * {rnn branch: linear ->
causal depthwise conv1d (K=4) -> RG-LRU} -> output linear -> residual.

The temporal conv is expressed through the library's 1-D stencil
(`core.stencil.conv1d_depthwise`, a degenerate §III-D stencil); the linear
recurrence h_t = a_t h_{t-1} + b_t runs as `jax.lax.associative_scan`
(parallel prefix — GSPMD-friendly) for train/prefill and as a single
fused step for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import stencil as st
from repro.models import common

Array = jax.Array

_C = 8.0  # RG-LRU exponent constant
_CONV_K = 4


def rglru_init(key, cfg) -> dict:
    d = cfg.d_model
    dt = cfg.np_dtype
    ks = jax.random.split(key, 7)
    return {
        "norm": common.norm_init(cfg.norm, d),
        "w_gate_branch": common.truncated_normal_init(ks[0], (d, d), 1.0, dt),
        "w_rnn_in": common.truncated_normal_init(ks[1], (d, d), 1.0, dt),
        "conv_w": common.truncated_normal_init(ks[2], (_CONV_K, d), 1.0, jnp.float32),
        "w_a": common.truncated_normal_init(ks[3], (d, d), 1.0, jnp.float32),
        "w_x": common.truncated_normal_init(ks[4], (d, d), 1.0, jnp.float32),
        # Lambda init so that a = sigmoid(L)^c is in [0.9, 0.999]
        "lam": jnp.asarray(
            jnp.log(jnp.exp(-jnp.log(jnp.linspace(0.9, 0.999, d)) / _C) - 1.0) * -1.0,
            jnp.float32,
        ),
        "w_out": common.truncated_normal_init(ks[5], (d, d), 1.0, dt),
    }


def _rglru_coeffs(p: dict, u: Array) -> tuple[Array, Array]:
    """u: conv output (B, S, D) -> (a_t, b_t) of the recurrence (fp32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"])
    i = jax.nn.sigmoid(uf @ p["w_x"])
    log_a = -_C * r * jax.nn.softplus(-p["lam"])  # log sigmoid(lam)^(c*r)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)
    return a, b


def rglru_apply(p: dict, cfg, x: Array, *, return_state: bool = False):
    h = common.apply_norm(cfg.norm, p["norm"], x)
    from jax.sharding import PartitionSpec as P
    from repro.sharding.partition import BATCH, constrain
    # channel-shard the recurrence on 'model' (elementwise over D -> the
    # associative scan shards cleanly; D=2560 divides the 16-way axis)
    gate = constrain(jax.nn.gelu(h @ p["w_gate_branch"]), P(BATCH, None, "model"))
    u_in = constrain(h @ p["w_rnn_in"], P(BATCH, None, "model"))
    u = st.conv1d_depthwise(u_in, p["conv_w"].astype(u_in.dtype))
    a, b = _rglru_coeffs(p, u)

    # parallel linear recurrence over S (axis 1)
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    out = x + y
    if return_state:
        state = {
            "h": hs[:, -1].astype(jnp.float32),
            "conv": u_in[:, -(_CONV_K - 1):].astype(jnp.float32),
        }
        return out, state
    return out


def rglru_init_state(cfg, batch: int) -> dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, d), jnp.float32),
    }


def rglru_decode(p: dict, cfg, x1: Array, state: dict) -> tuple[Array, dict]:
    b, s, d = x1.shape  # s == 1
    h = common.apply_norm(cfg.norm, p["norm"], x1)
    gate = jax.nn.gelu(h @ p["w_gate_branch"])
    u = (h @ p["w_rnn_in"])[:, 0].astype(jnp.float32)  # (B, D)
    # sliding conv buffer: state["conv"] holds the last K-1 inputs
    window = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B, K, D)
    uc = jnp.einsum("bkd,kd->bd", window, p["conv_w"])
    a, bcoef = _rglru_coeffs(p, uc[:, None])
    a, bcoef = a[:, 0], bcoef[:, 0]
    h_new = a * state["h"] + bcoef
    y = (h_new[:, None].astype(x1.dtype) * gate) @ p["w_out"]
    return x1 + y, {"h": h_new, "conv": window[:, 1:]}
