"""Per-arch smoke tests (deliverable (f)): every assigned architecture's
reduced config runs one forward/train step on CPU with finite loss and
correct shapes, plus decode-vs-forward consistency for each family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)
B, S = 2, 48


def build(arch):
    cfg = configs.get_config(arch + "-smoke")
    params = tf.init_params(KEY, cfg)
    frontend = None
    if cfg.encoder_layers or cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
        )
    return cfg, params, frontend


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_smoke(arch):
    cfg, params, frontend = build(arch)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    loss, grads = jax.value_and_grad(
        lambda p: tf.loss_fn(p, cfg, tokens, labels, frontend=frontend)
    )(params)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, dtype=np.float32))) for l in leaves)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes(arch):
    cfg, params, frontend = build(arch)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    h, aux = tf.forward(params, cfg, tokens, frontend=frontend)
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))
    logits = tf._logits_chunk(params, cfg, h[:, -1:])
    assert logits.shape == (B, 1, cfg.vocab)


def _merge_cache(cache0, cache):
    def merge(dst, src):
        if isinstance(dst, dict):
            return {k: merge(dst[k], src[k]) for k in dst}
        if isinstance(dst, list):
            return [merge(a, b) for a, b in zip(dst, src)]
        if dst.ndim == src.ndim and dst.shape != src.shape:
            sl = [slice(None)] * dst.ndim
            sl[-2] = slice(0, src.shape[-2])
            return dst.at[tuple(sl)].set(src)
        return src

    return [merge(c0, c) for c0, c in zip(cache0, cache)]


@pytest.mark.parametrize(
    "arch",
    [
        "qwen2-7b",           # dense GQA full attention
        "mixtral-8x7b",       # MoE + SWA ring cache
        "recurrentgemma-2b",  # RG-LRU + local attention hybrid
        "xlstm-125m",         # recurrent states
        "seamless-m4t-large-v2",  # enc-dec cross caches
        "llama-3.2-vision-90b",   # VLM cross-attn layers
        "deepseek-moe-16b",   # shared+routed MoE (dropless decode capacity)
    ],
)
def test_decode_matches_forward(arch):
    cfg, params, frontend = build(arch)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    h, _ = tf.forward(params, cfg, toks, frontend=frontend)
    logits_full = tf._logits_chunk(params, cfg, h[:, -1:])[:, 0]
    _, cache = tf.prefill(params, cfg, toks[:, :S], frontend=frontend)
    cache = _merge_cache(tf.init_cache(cfg, B, S + 8), cache)
    src = None
    if not cfg.encoder_layers and cfg.n_frontend_tokens:
        src = frontend.astype(cfg.np_dtype)
    logits_d, _ = tf.decode_step(
        params, cfg, toks[:, S], cache, jnp.int32(S), frontend_src=src
    )
    err = float(jnp.max(jnp.abs(logits_d - logits_full)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    # MoE full-forward uses finite capacity (legit token dropping), so
    # tolerate slightly more there; bf16 noise otherwise.
    tol = 0.08 if cfg.moe is not None else 0.05
    assert err / scale < tol, f"{arch}: rel err {err/scale:.4f}"


def test_moe_dense_vs_sort_dispatch_agree():
    cfg = configs.get_config("deepseek-moe-16b-smoke")
    from repro.models import moe as moe_mod

    key = jax.random.PRNGKey(3)
    p = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32).astype(cfg.np_dtype)
    cap = 2 * 16 * cfg.moe.top_k  # dropless
    yd, _ = moe_mod.moe_dense(p, cfg, x, capacity=cap)
    ys, _ = moe_mod.moe_sort(p, cfg, x, capacity=cap)
    np.testing.assert_allclose(
        np.asarray(yd, np.float32), np.asarray(ys, np.float32), rtol=2e-2, atol=2e-2
    )


def test_flash_vs_exact_attention():
    from repro.models import attention as attn

    key = jax.random.PRNGKey(0)
    b, hq, hkv, s, d = 2, 8, 2, 96, 32
    q = jax.random.normal(key, (b, hq, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), jnp.float32)
    for chunk in (16, 32, 96, 100):
        out = attn.flash_attention(q, k, v, causal=True, chunk=chunk)
        # exact reference: full softmax with causal mask
        qg = q.reshape(b, hkv, hq // hkv, s, d)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * (d**-0.5)
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
        want = jnp.einsum(
            "bhgqk,bhkd->bhgqd", jax.nn.softmax(logits, -1), v
        ).reshape(b, hq, s, d)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4
        )


def test_local_attention_matches_masked_full():
    from repro.models import attention as attn

    key = jax.random.PRNGKey(0)
    b, h, s, d, w = 1, 2, 64, 16, 16
    q = jax.random.normal(key, (b, h, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32)
    out = attn.local_attention(q, k, v, window=w)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (d**-0.5)
    qpos, kpos = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = (qpos >= kpos) & (kpos > qpos - w)
    logits = jnp.where(mask, logits, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)
