"""Paper Fig. 1: read/write kernel bandwidth over data sizes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import memcpy_gbps, row, time_fn
from repro.kernels import ops


def run() -> list[str]:
    out = [f"# memcpy baseline: {memcpy_gbps():.2f} GB/s"]
    copy = jax.jit(ops.copy)
    for mb in (4, 16, 64, 256):
        n = mb * 1024 * 1024 // 4
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
        x = x.reshape(-1, 1024)
        t = time_fn(copy, x)
        out.append(row(f"copy_{mb}MB", t, 2 * x.nbytes))
    # ranged read
    x = jnp.asarray(np.random.default_rng(0).standard_normal((65536, 1024)), jnp.float32)
    t = time_fn(jax.jit(lambda a: ops.copy_range(a, jnp.int32(123), 32768)), x)
    out.append(row("copy_range_128MB", t, 2 * 32768 * 1024 * x.dtype.itemsize))
    # index-set gather (random permutation rows); traffic counts the data
    # rows both ways plus the int32 index-table stream
    idx = jnp.asarray(np.random.default_rng(1).permutation(65536), jnp.int32)
    t = time_fn(jax.jit(ops.gather_rows), x, idx)
    out.append(row("gather_rows_256MB", t, 2 * x.nbytes + idx.nbytes))
    return out
