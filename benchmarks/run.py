"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only copy,permute,...]

Prints ``name,us_per_call,derived`` CSV per row (derived = achieved GB/s
and fraction of host memcpy — the paper's normalization).
"""

from __future__ import annotations

import argparse
import sys
import time

SUITES = [
    ("copy", "benchmarks.bench_copy", "Fig. 1 read/write kernels"),
    ("permute", "benchmarks.bench_permute", "Table 1 3D permute"),
    ("reorder", "benchmarks.bench_reorder", "Table 2 generic reorder"),
    ("interlace", "benchmarks.bench_interlace", "Table 3 interlace/deinterlace"),
    ("stencil", "benchmarks.bench_stencil", "Fig. 2/Table 4 2D FD stencil"),
    ("moe_dispatch", "benchmarks.bench_moe_dispatch", "beyond-paper MoE dispatch"),
    ("roofline", "benchmarks.bench_roofline", "dry-run roofline table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    for key, module, title in SUITES:
        if only and key not in only:
            continue
        t0 = time.time()
        print(f"# === {title} ({module}) ===", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"# {key} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            print(f"{key},error,{type(e).__name__}")
        print(f"# ({time.time()-t0:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
