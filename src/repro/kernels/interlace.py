"""Interlace / de-interlace kernels (paper §III-C), TPU-native.

AoS <-> SoA conversion: n arrays of length L interleaved element-wise into
one array of length n*L (and back).  The CUDA version stages 8x8 blocks in
shared memory with n*64 threads so that both the global load and the global
store stay coalesced; the interleaving shuffle happens in shared memory.

TPU version: the key observation is that for a column block of width
``bc``, the interleaved output of that block is a *contiguous* run of
``n*bc`` elements.  So:

  load   n lane-aligned tiles (1, bc)    — one per source array (coalesced),
  shuffle in VMEM:  rows.T.reshape(n,bc) — the VREG transpose,
  store  one lane-aligned tile (n, bc)   — contiguous in the output (coalesced).

Shared memory -> VMEM, warp shuffle -> VPU transpose, and the 8x8 block
becomes an (n, bc) tile sized for (8,128) registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANES, VMEM_BUDGET, force_interpret


def _pick_bc(L: int, n: int, itemsize: int) -> int:
    """Largest power-of-two column block dividing L within VMEM budget.

    Prefers lane multiples (>= 128); lengths with only a small power-of-two
    factor still get a (narrower, slower) kernel block, and lengths with no
    usable factor raise so dispatch falls back to the oracle.
    """
    if L == 0:
        raise ValueError("empty arrays: no kernel block (oracle handles L=0)")
    budget_elems = VMEM_BUDGET // (2 * itemsize * max(n, 1))
    bc = 1
    while bc * 2 <= min(budget_elems, 16384) and L % (bc * 2) == 0:
        bc *= 2
    if bc < 8:
        raise ValueError(f"L={L} has no usable power-of-two block (got {bc})")
    return bc


def _interlace_kernel(n, bc, *refs):
    o_ref = refs[-1]
    rows = jnp.concatenate([r[...] for r in refs[:-1]], axis=0)  # (n, bc)
    # out[j*n + k] = rows[k, j]  ==  row-major flat of rows.T
    o_ref[...] = rows.T.reshape(n, bc)


def _deinterlace_kernel(n, bc, x_ref, *o_refs):
    run = x_ref[...].reshape(bc, n)  # run[j, k] = flat[j*n + k]
    for k, o_ref in enumerate(o_refs):
        o_ref[...] = run[:, k].reshape(1, bc)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def interlace(
    arrays: tuple[jax.Array, ...],
    *,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """n 1-D arrays (L,) -> (n*L,) with out[j*n + k] = arrays[k][j]."""
    n = len(arrays)
    L = arrays[0].shape[0]
    for a in arrays:
        if a.shape != (L,) or a.dtype != arrays[0].dtype:
            raise ValueError("interlace requires same-shape/dtype 1-D arrays")
    dtype = arrays[0].dtype
    bc = block_c or _pick_bc(L, n, jnp.dtype(dtype).itemsize)
    if L % bc:
        raise ValueError(f"L={L} not divisible by block_c={bc}")
    g = L // bc
    views = [a.reshape(g, bc) for a in arrays]

    interpret = force_interpret() if interpret is None else interpret
    out2d = pl.pallas_call(
        functools.partial(_interlace_kernel, n, bc),
        grid=(g,),
        in_specs=[pl.BlockSpec((1, bc), lambda i: (i, 0)) for _ in range(n)],
        out_specs=pl.BlockSpec((n, bc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g * n, bc), dtype),
        interpret=interpret,
    )(*views)
    return out2d.reshape(n * L)


@functools.partial(jax.jit, static_argnames=("n", "block_c", "interpret"))
def deinterlace(
    x: jax.Array,
    n: int,
    *,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, ...]:
    """(n*L,) -> n arrays (L,): inverse of :func:`interlace`."""
    if x.ndim != 1 or x.shape[0] % n:
        raise ValueError(f"bad shape {x.shape} for n={n}")
    L = x.shape[0] // n
    bc = block_c or _pick_bc(L, n, jnp.dtype(x.dtype).itemsize)
    if L % bc:
        raise ValueError(f"L={L} not divisible by block_c={bc}")
    g = L // bc
    xview = x.reshape(g * n, bc)

    interpret = force_interpret() if interpret is None else interpret
    outs = pl.pallas_call(
        functools.partial(_deinterlace_kernel, n, bc),
        grid=(g,),
        in_specs=[pl.BlockSpec((n, bc), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, bc), lambda i: (i, 0)) for _ in range(n)],
        out_shape=[jax.ShapeDtypeStruct((g, bc), x.dtype) for _ in range(n)],
        interpret=interpret,
    )(xview)
    return tuple(o.reshape(L) for o in outs)
