"""Minitron-8B [arXiv:2407.14679; hf] — pruned Nemotron-4: GQA kv=8,
squared-ReLU MLP, LayerNorm, untied embeddings, vocab 256k."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    head_dim=128,
    qkv_bias=False,
    act="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    tie_embeddings=False,
    unit=("attn",),
    source="arXiv:2407.14679 (hf: nvidia/Minitron-8B-Base)",
)
