"""Generic 2-D stencil kernels (paper §III-D), TPU-native — single-sweep
functor stencils and fused multi-stage pipelines (DESIGN.md §9).

The CUDA kernel loads a 34x34 halo'd tile for a 32x32 block (overlapping,
partially uncoalesced apron loads; texture-memory variants to soften the
misalignment) and takes a *functor* for the per-point computation so any
stencil compiles to a specialized kernel.

TPU version:
* row-panel decomposition: each grid step owns a (block_rows, W) panel with
  the full row width resident in VMEM — column halos are then free (they
  are just lane shifts within the panel), which deletes the paper's
  misaligned-apron problem instead of patching it with texture fetches.
* the row halo is expressed by passing the input again with small
  halo-block specs above and below the owned panel (clamped index maps).
  The Pallas pipeline DMAs each as a lane-aligned tile — the overlap costs
  ``2*halo_rows/block_rows`` extra reads per panel, the same apron
  redundancy the paper reports, but every load stays aligned.
* **temporal blocking** (`stencil2d_pipeline`): a program of k stages is
  applied entirely in VMEM.  The panel is loaded once with a halo of
  ``sum(radius_i)`` rows; each stage consumes its radius from the halo
  (shrink-and-mask) and the final stage's panel is the only store.  One
  HBM round trip replaces k.
* the boundary-condition family ``zero | nearest | reflect | periodic`` is
  resolved per stage against *global* row indices (which also kills OOB
  garbage in the final partial panel) plus a boundary-correct column pad.
* functors run at **trace time** — the exact analogue of the paper's
  compile-time C++ functor: any jnp expression over ``shift(dy, dx)`` views
  specializes the kernel with no interpretive overhead.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BOUNDARY_PAD_MODES
from repro.kernels.tiling import (
    VMEM_BYTES,
    cdiv,
    force_interpret,
    round_up,
    sublanes,
)

# the supported boundary-condition family, derived from the oracle's pad
# table so the two can never drift ('clamp' is a legacy 'nearest' alias).
BOUNDARIES = tuple(BOUNDARY_PAD_MODES)

Stage = tuple[Callable[..., jax.Array], int]


@functools.lru_cache(maxsize=512)
def _linear_functor(offsets: tuple, weights: tuple) -> Callable:
    """Build (and memoize) the weighted-sum functor for a linear stencil.

    Memoizing on the (offsets, weights) table keeps the functor's identity
    stable across calls, so jit tracing caches hit instead of respecializing
    the kernel for every invocation of the same stencil.
    """

    def functor(shift, *_unused):
        acc = None
        for (dy, dx), w in zip(offsets, weights):
            term = w * shift(dy, dx)
            acc = term if acc is None else acc + term
        return acc

    return functor


def _smallest_divisor_at_least(n: int, lo: int) -> int:
    """Smallest divisor of ``n`` that is >= ``lo`` (``n`` itself worst case)."""
    for d in range(max(lo, 1), n):
        if n % d == 0:
            return d
    return n


def pick_panel(
    H: int,
    W: int,
    dtype,
    total_radius: int,
    boundary: str,
    block_rows: int | None = None,
) -> tuple[int, int, bool]:
    """Choose the fused kernel's row-panel configuration.

    Returns ``(block_rows, halo_block_rows, wrap_local)``:

    * ``block_rows`` — rows owned per grid step;
    * ``halo_block_rows`` — row count of the above/below halo blocks (a
      divisor of ``block_rows`` so halo offsets stay block-aligned); 0 when
      the program needs no halo;
    * ``wrap_local`` — periodic-only single-panel mode: the whole grid is
      VMEM-resident and the wrap halo is built from resident rows.

    Raises ``ValueError`` when no fused configuration exists for the shape
    (the dispatch layer then falls back to per-sweep sweeps — the library
    never fails on an awkward shape, it just loses the fast path).
    """
    sl = sublanes(dtype)
    itemsize = jnp.dtype(dtype).itemsize
    R = int(total_radius)
    if H <= 0 or W <= 0:
        raise ValueError("empty grid has no fused path")

    if boundary == "periodic":
        # periodic halos wrap across panels, which is only exact when the
        # panel size divides H (no partial panel to misalign the wrap).
        if block_rows is not None:
            br = int(block_rows)
            if br >= H:
                br = H
            elif H % br or br < max(R, 1):
                raise ValueError(
                    f"periodic needs block_rows dividing H and >= radius; "
                    f"got {block_rows} for H={H}, radius={R}"
                )
        else:
            divs = [d for d in range(max(R, 1), H + 1) if H % d == 0]
            br = min(divs, key=lambda d: (d % sl != 0, abs(d - 64))) if divs else H
        wrap_local = br >= H
        rp = 0 if wrap_local else _smallest_divisor_at_least(br, R)
    else:
        wrap_local = False
        if R == 0:
            rp = 0
            br = int(block_rows) if block_rows is not None else max(sl, min(64, H))
        else:
            if block_rows is not None:
                br = int(block_rows)
                if br < R:
                    raise ValueError(f"block_rows {br} < total radius {R}")
                rp = _smallest_divisor_at_least(br, R)
            else:
                rp = round_up(R, sl)
                br = round_up(max(min(64, H), sl, R), rp)

    # conservative VMEM sanity: halo'd working panel plus pipeline buffers,
    # plus the (T, T) one-hot boundary-gather matrix and f32 panel cast the
    # nearest/reflect paths build per stage
    T = br + 2 * R
    need = T * (W + 2 * R) * itemsize * 6
    if boundary in ("nearest", "clamp", "reflect"):
        need += T * T * 4 + T * (W + 2 * R) * 4
    if need > VMEM_BYTES:
        raise ValueError(
            f"fused stencil panel ({br}+2*{R}, {W}) exceeds the VMEM budget"
        )
    return br, rp, wrap_local


def _pipeline_kernel(
    stages, boundary, br, rp, H, W, R, has_aux, wrap_local, h_glob, has_row0,
    *refs,
):
    i = pl.program_id(0)
    o_ref = refs[-1]
    n_per = 1 if (R == 0 or wrap_local) else 3
    x_refs = refs[:n_per]
    pos_ref = n_per + (n_per if has_aux else 0)
    a_refs = refs[n_per:pos_ref] if has_aux else ()
    # global-row window (§10 halo exchange): row 0 of this array sits at
    # global row `row0v` of a `h_glob`-row grid, so boundary masks fire at
    # the TRUE grid edges, not the shard edges.  Single-device calls pass
    # no row0 operand and h_glob == H — identical arithmetic to before.
    row0v = refs[pos_ref][0, 0] if has_row0 else 0

    def band(rs):
        # assemble the halo'd panel: nominal global rows [i*br - R, (i+1)*br + R)
        if wrap_local:
            # single panel owns the whole grid (br == H): the periodic halo
            # is built from resident rows, m wraps deep when R > H
            c = rs[0][...]
            m = cdiv(R, H) if R else 0
            big = jnp.concatenate([c] * (2 * m + 1), axis=0) if m else c
            return jax.lax.slice_in_dim(big, m * H - R, m * H + H + R, axis=0)
        if R == 0:
            return rs[0][...]
        t = jnp.concatenate([rs[0][...], rs[1][...], rs[2][...]], axis=0)
        return jax.lax.slice_in_dim(t, rp - R, rp + br + R, axis=0)

    tile = band(x_refs)
    atile = band(a_refs) if has_aux else None
    if has_aux and boundary != "periodic":
        # zero OOB aux rows so final-partial-panel garbage (possibly NaN)
        # cannot poison rows that survive the shrink
        ea = jax.lax.broadcasted_iota(jnp.int32, (br + 2 * R, 1), 0) + i * br - R
        ga = ea + row0v
        a_ok = (ga >= 0) & (ga < h_glob)
        if has_row0:
            # window mode: padding rows past the local array can sit inside
            # the global domain (see the x-path mask below) — zero them too
            a_ok = a_ok & (ea >= 0) & (ea < H)
        atile = jnp.where(a_ok, atile, jnp.zeros((), atile.dtype))

    h = R
    for functor, r in stages:
        T = br + 2 * h
        g0 = i * br - h + row0v
        # global row ids of the current band (2-D iota — Mosaic wants >=2-D)
        g = jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0) + g0
        if boundary == "periodic":
            # periodic content is already the wrapped extension (mod index
            # maps / wrap_local assembly / resident halo rows) and stays so
            # under each stage
            cur = tile
        else:
            inside = (g >= 0) & (g < h_glob)
            if has_row0:
                # window mode: rows past the local array (final-partial-panel
                # padding) can sit INSIDE the global domain, so the global
                # mask alone would keep their garbage (possibly NaN, which
                # the regather dot then spreads).  Zero them — everything
                # depending on them is in the cropped apron.
                eg = g - row0v
                inside = inside & (eg >= 0) & (eg < H)
            cur = jnp.where(inside, tile, jnp.zeros((), tile.dtype))
            if boundary != "zero":
                # re-extend the boundary from in-domain rows: a one-hot
                # row-gather (pos may fall outside the band for rows deeper
                # than this stage needs; those resolve to 0 and are shrunk
                # away before they can matter).  Panels whose band lies
                # fully in-domain skip it — the gather would be identity.
                if boundary == "reflect" and h_glob > 1:
                    p = 2 * h_glob - 2
                    m = g % p
                    src = jnp.where(m < h_glob, m, p - m)
                else:  # nearest / clamp (and reflect on a 1-row grid)
                    src = jnp.clip(g, 0, h_glob - 1)
                pos = src - g0
                cols = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)

                def _regather(c, _pos=pos, _cols=cols):
                    sel = (_cols == _pos).astype(jnp.float32)
                    return jax.lax.dot_general(
                        sel,
                        c.astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32,
                    ).astype(c.dtype)

                touches_edge = (g0 < 0) | (g0 + T > h_glob)
                cur = jax.lax.cond(touches_edge, _regather, lambda c: c, cur)
        # column halo: boundary-correct pad of r lanes per side (the full
        # row is resident, so these are static lane shifts — free)
        if r == 0:
            curp = cur
        elif boundary == "zero":
            curp = jnp.pad(cur, ((0, 0), (r, r)))
        elif boundary in ("nearest", "clamp"):
            left = jnp.broadcast_to(jax.lax.slice(cur, (0, 0), (T, 1)), (T, r))
            right = jnp.broadcast_to(jax.lax.slice(cur, (0, W - 1), (T, W)), (T, r))
            curp = jnp.concatenate([left, cur, right], axis=1)
        elif boundary == "reflect":
            left = jax.lax.rev(jax.lax.slice(cur, (0, 1), (T, r + 1)), (1,))
            right = jax.lax.rev(jax.lax.slice(cur, (0, W - r - 1), (T, W - 1)), (1,))
            curp = jnp.concatenate([left, cur, right], axis=1)
        else:  # periodic
            left = jax.lax.slice(cur, (0, W - r), (T, W))
            right = jax.lax.slice(cur, (0, 0), (T, r))
            curp = jnp.concatenate([left, cur, right], axis=1)

        h2 = h - r
        rows_out = br + 2 * h2

        def shift(dy: int, dx: int, _curp=curp, _r=r, _rows=rows_out):
            if max(abs(dy), abs(dx)) > _r:
                raise ValueError(f"shift ({dy},{dx}) exceeds stage radius {_r}")
            return jax.lax.slice(
                _curp, (_r + dy, _r + dx), (_r + dy + _rows, _r + dx + W)
            )

        if has_aux:
            def src_view(_a=atile, _h2=h2, _rows=rows_out):
                return jax.lax.slice(_a, (R - _h2, 0), (R - _h2 + _rows, W))

            tile = functor(shift, src_view)
        else:
            tile = functor(shift)
        h = h2
    o_ref[...] = tile.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "stages", "boundary", "block_rows", "global_rows", "halo_resident",
        "interpret",
    ),
)
def stencil2d_pipeline(
    x: jax.Array,
    stages: Sequence[Stage],
    *,
    boundary: str = "zero",
    aux: jax.Array | None = None,
    block_rows: int | None = None,
    row0: jax.Array | None = None,
    global_rows: int | None = None,
    halo_resident: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Run a multi-stage stencil program in ONE fused `pallas_call`.

    ``stages`` is a tuple of ``(functor, radius)`` pairs; each functor is
    called as ``functor(shift)`` (or ``functor(shift, src)`` when ``aux``
    is given, where ``src()`` yields the aux band, e.g. a Poisson source
    term).  Stages apply in sequence with the boundary condition re-applied
    between them — semantically identical to ``len(stages)`` full-grid
    sweeps (`ref.stencil_pipeline`) but with a single HBM round trip via
    temporal blocking: each grid panel loads a ``sum(radius_i)``-row halo
    once, runs every stage in VMEM, and stores once.

    Global-row window (the §10 halo-exchange hook): when ``x`` is a
    halo-extended shard of a larger grid, ``row0`` (a traced int32 scalar,
    fed to the kernel as a (1, 1) operand) gives the global row of ``x``'s
    row 0 and ``global_rows`` the full grid height, so every boundary mask
    fires at the true grid edges.  ``halo_resident=True`` marks periodic
    wrap rows as physically present in ``x`` (the ring exchange delivered
    them), switching periodic to the clamped halo BlockSpecs.  Rows whose
    dependency cone leaves ``x`` come out contaminated and must be cropped
    by the caller (the ``sum(radius_i)`` apron — `core/dist_plan.py` does).
    """
    if x.ndim != 2:
        raise ValueError(f"stencil pipeline wants 2-D input, got {x.shape}")
    if boundary not in BOUNDARIES:
        raise ValueError(f"unknown boundary {boundary!r}; want one of {BOUNDARIES}")
    stages = tuple((f, int(r)) for f, r in stages)
    if not stages:
        raise ValueError("empty stencil program")
    if any(r < 0 for _, r in stages):
        raise ValueError("negative stage radius")
    H, W = x.shape
    R = sum(r for _, r in stages)
    for _, r in stages:
        if r and boundary == "reflect" and W < r + 1:
            raise ValueError(f"reflect columns need W >= radius+1, got W={W}")
        if r and boundary == "periodic" and W < r:
            raise ValueError(f"periodic columns need W >= radius, got W={W}")
    has_aux = aux is not None
    if has_aux and aux.shape != x.shape:
        raise ValueError(f"aux shape {aux.shape} != grid shape {x.shape}")
    has_row0 = row0 is not None
    h_glob = H if global_rows is None else int(global_rows)

    # resident periodic halos (§10): the wrap rows were delivered by the
    # ring exchange, so panel geometry and index maps use the clamped
    # (non-wrapping) family; the kernel's periodic path needs no row masks.
    geo_boundary = "zero" if (halo_resident and boundary == "periodic") else boundary
    br, rp, wrap_local = pick_panel(H, W, x.dtype, R, geo_boundary, block_rows)
    nb = cdiv(H, br)
    interpret = force_interpret() if interpret is None else interpret

    def im_cur(i):
        return (i, 0)

    if wrap_local or R == 0:
        per_input = [pl.BlockSpec((br, W), im_cur)]
    else:
        q = br // rp
        nq = cdiv(H, rp)
        if geo_boundary == "periodic":
            below = lambda i: ((i * q - 1) % nq, 0)  # noqa: E731
            above = lambda i: (((i + 1) * q) % nq, 0)  # noqa: E731
        else:
            below = lambda i: (jnp.maximum(i * q - 1, 0), 0)  # noqa: E731
            above = lambda i: (jnp.minimum((i + 1) * q, nq - 1), 0)  # noqa: E731
        per_input = [
            pl.BlockSpec((rp, W), below),
            pl.BlockSpec((br, W), im_cur),
            pl.BlockSpec((rp, W), above),
        ]

    operands = [x] * len(per_input)
    in_specs = list(per_input)
    if has_aux:
        operands += [aux] * len(per_input)
        in_specs += list(per_input)
    if has_row0:
        # (1, 1) int32 scalar operand, broadcast to every panel
        operands.append(jnp.asarray(row0, jnp.int32).reshape(1, 1))
        in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))

    return pl.pallas_call(
        functools.partial(
            _pipeline_kernel,
            stages,
            boundary,
            br,
            rp,
            H,
            W,
            R,
            has_aux,
            wrap_local,
            h_glob,
            has_row0,
        ),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, W), im_cur),
        out_shape=jax.ShapeDtypeStruct((H, W), x.dtype),
        interpret=interpret,
    )(*operands)


def stencil2d_functor(
    x: jax.Array,
    functor: Callable,
    radius: int,
    *,
    boundary: str = "zero",
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply a generic stencil functor over a 2-D grid (single sweep).

    ``functor(shift)`` -> Array, where ``shift(dy, dx)`` yields the panel
    shifted by (dy, dx).  See ``repro.kernels.ref.stencil2d_functor`` for
    the oracle semantics.  A one-stage special case of
    :func:`stencil2d_pipeline`.
    """
    return stencil2d_pipeline(
        x,
        ((functor, int(radius)),),
        boundary=boundary,
        block_rows=block_rows,
        interpret=interpret,
    )


def stencil2d(
    x: jax.Array,
    offsets,
    weights,
    *,
    boundary: str = "zero",
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Weighted-sum stencil via the functor kernel (single sweep)."""
    offs = tuple((int(dy), int(dx)) for dy, dx in offsets)
    wts = tuple(float(w) for w in weights)
    radius = max(max(abs(dy), abs(dx)) for dy, dx in offs)
    return stencil2d_functor(
        x,
        _linear_functor(offs, wts),
        radius,
        boundary=boundary,
        block_rows=block_rows,
        interpret=interpret,
    )
