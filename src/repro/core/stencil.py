"""Generic stencil API (paper §III-D): stencils and stencil *programs* as
first-class objects.

The paper ships the stencil as a C++ functor compiled into the kernel; we
ship it as a trace-time Python functor (or an (offsets, weights) table)
compiled into the Pallas kernel.  ``Stencil`` objects compose: scale, add,
``then`` (sequential stages) and ``repeat`` (k sweeps) build a
:class:`StencilProgram` that the plan engine lowers to ONE fused
`pallas_call` via temporal blocking (DESIGN.md §9) — the iterative-workload
analogue of the rearrangement planner in `core/plan.py`:

1. **describe** — a program is a tuple of stage descriptors (linear
   (offsets, weights) tables and/or trace-time functors with a radius);
2. **plan** — :func:`plan_stencil` picks the row-panel configuration and
   predicts HBM traffic for the fused pipeline vs per-sweep execution;
3. **cache** — plans are memoized on (shape, dtype, stages, boundary,
   has_aux), so steady-state solvers (e.g. the CFD cavity example) pay
   zero planning or retracing overhead after the first step.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import tune
from repro.core.plan import HBM_GBPS
from repro.kernels import ops, ref
from repro.kernels import stencil2d as st_k
from repro.kernels.tiling import cdiv, neighborhood, round_up, sublanes
from repro.utils.roofline import movement_cost_s

Array = jax.Array

#: boundary-condition family accepted by every stencil entry point, derived
#: from the oracle's pad table (kernels/ref.py) so the copies cannot drift;
#: the legacy alias ``'clamp'`` (= nearest) is accepted but not advertised.
BOUNDARIES = tuple(b for b in ref.BOUNDARY_PAD_MODES if b != "clamp")


@dataclass(frozen=True)
class Stencil:
    """A linear stencil: ``out[p] = sum_k weights[k] * in[p + offsets[k]]``.

    Example::

        lap = Stencil(((0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)),
                      (-4.0, 1.0, 1.0, 1.0, 1.0))
        y = lap(x)                       # one sweep, zero boundary
        y = lap(x, boundary="reflect")   # any of the four boundary modes
        prog = lap.repeat(8)             # 8 fused sweeps, ONE kernel
    """

    offsets: tuple[tuple[int, int], ...]
    weights: tuple[float, ...]

    @property
    def radius(self) -> int:
        """Chebyshev radius of the stencil's footprint."""
        return max(max(abs(dy), abs(dx)) for dy, dx in self.offsets)

    def __call__(self, x: Array, *, boundary: str = "zero") -> Array:
        """Apply one sweep of the stencil to a 2-D grid ``x``."""
        return ops.stencil2d(x, self.offsets, self.weights, boundary=boundary)

    def scale(self, a: float) -> "Stencil":
        """New stencil with every weight multiplied by ``a``."""
        return Stencil(self.offsets, tuple(a * w for w in self.weights))

    def __add__(self, other: "Stencil") -> "Stencil":
        table: dict[tuple[int, int], float] = {}
        for off, w in zip(self.offsets, self.weights):
            table[off] = table.get(off, 0.0) + w
        for off, w in zip(other.offsets, other.weights):
            table[off] = table.get(off, 0.0) + w
        offs = tuple(sorted(table))
        return Stencil(offs, tuple(table[o] for o in offs))

    def as_program(self) -> "StencilProgram":
        """Lift this stencil into a one-stage :class:`StencilProgram`."""
        return StencilProgram((("linear", self.offsets, self.weights),))

    def then(self, other: "Stencil | StencilProgram") -> "StencilProgram":
        """Sequential composition: ``self`` then ``other`` (one fused kernel).

        Example::

            prog = box_blur(1).then(fd_laplacian(1))  # blur, then laplacian
            y = prog(x)                               # ONE pallas_call
        """
        return self.as_program().then(other)

    def repeat(self, k: int) -> "StencilProgram":
        """``k`` fused sweeps of this stencil (temporal blocking).

        Example::

            jacobi = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)
            y = jacobi.repeat(8)(x)   # == 8 sequential sweeps, ONE kernel
        """
        return self.as_program().repeat(k)


@dataclass(frozen=True)
class StencilPlan:
    """Compiled lowering decision for a stencil program on a given grid.

    Mirrors :class:`repro.core.plan.RearrangePlan`: routing (`mode`), the
    chosen panel geometry, and the predicted HBM traffic of the fused
    pipeline vs per-sweep execution so callers and benchmarks can compare
    achieved vs predicted movement.
    """

    mode: str  # fused | reference
    kernel: str  # stencil2d_pipeline | ref.stencil_pipeline
    shape: tuple[int, int]
    boundary: str
    n_stages: int
    total_radius: int
    block_rows: int  # rows owned per grid panel (0 on the reference path)
    halo_block_rows: int  # halo block height loaded above/below each panel
    grid: int  # number of row panels
    bytes_moved: int  # fused-path HBM traffic (reads incl. halo + 1 write)
    bytes_per_sweep_path: int  # traffic of n_stages separate sweeps
    roofline_s: float  # fused bytes / HBM bandwidth (one chip)
    stages_exec: tuple = field(repr=False, hash=False, compare=False)

    def describe(self) -> str:
        """One-line human-readable summary (benchmarks / debugging)."""
        saving = self.bytes_per_sweep_path / max(self.bytes_moved, 1)
        return (
            f"{self.mode}: shape={self.shape} stages={self.n_stages} "
            f"radius={self.total_radius} boundary={self.boundary} "
            f"panel=({self.block_rows}+2*{self.halo_block_rows} halo rows)x{self.grid} "
            f"{self.bytes_moved/1e6:.2f} MB moved vs "
            f"{self.bytes_per_sweep_path/1e6:.2f} MB per-sweep ({saving:.1f}x), "
            f"roofline {self.roofline_s*1e6:.1f} us @ {HBM_GBPS} GB/s"
        )


def _stage_exec(desc) -> tuple[Callable, int]:
    """Materialize a stage descriptor into the kernel's (functor, radius)."""
    if desc[0] == "linear":
        _, offsets, weights = desc
        radius = max(max(abs(dy), abs(dx)) for dy, dx in offsets)
        return st_k._linear_functor(offsets, weights), radius
    _, functor, radius = desc
    return functor, int(radius)


def _build_plan(
    shape: tuple[int, int],
    dtype_name: str,
    stages: tuple,
    boundary: str,
    has_aux: bool,
    block_rows: int | None = None,
) -> StencilPlan:
    """Route one stencil program and materialize the plan.

    ``block_rows`` overrides the heuristic row-panel height (the tuner's
    hook; an illegal override raises so the tuner can skip the candidate);
    with ``None`` this is exactly the pre-tuner planner.
    """
    H, W = shape
    itemsize = jnp.dtype(dtype_name).itemsize
    stages_exec = tuple(_stage_exec(d) for d in stages)
    radii = tuple(r for _, r in stages_exec)
    R = sum(radii)
    n = H * W

    def col_ok(r: int) -> bool:
        if r == 0:
            return True
        if boundary == "reflect":
            return W >= r + 1
        if boundary == "periodic":
            return W >= r
        return True

    br = rp = 0
    mode = "reference"
    if n > 0 and all(col_ok(r) for r in radii):
        try:
            br, rp, _ = st_k.pick_panel(
                H, W, dtype_name, R, boundary, block_rows=block_rows
            )
            mode = "fused"
        except ValueError:
            if block_rows is not None:
                raise  # the tuner asked for an illegal panel: skip candidate
            br = rp = 0
    elif block_rows is not None:
        raise ValueError("no fused path to tune for this shape/boundary")
    grid = cdiv(H, br) if br else 0

    # cost model: useful traffic is one read + one write of the grid; the
    # fused path adds the apron redundancy (2*rp halo rows per panel, plus
    # a second operand stream when an aux/source grid rides along), while
    # the per-sweep path pays the full round trip once per stage.
    n_streams = 2 if has_aux else 1
    fused_reads = (n + 2 * rp * W * grid) * n_streams
    bytes_fused = (fused_reads + n) * itemsize
    sl = sublanes(dtype_name)
    per_sweep = 0
    for r in radii:
        rp_s = round_up(r, sl) if (r and br) else 0
        per_sweep += ((n + 2 * rp_s * W * (cdiv(H, br) if br else 0)) * n_streams + n)
    bytes_per_sweep = per_sweep * itemsize

    return StencilPlan(
        mode=mode,
        kernel="stencil2d_pipeline" if mode == "fused" else "ref.stencil_pipeline",
        shape=shape,
        boundary=boundary,
        n_stages=len(stages_exec),
        total_radius=R,
        block_rows=br,
        halo_block_rows=rp,
        grid=grid,
        bytes_moved=bytes_fused if mode == "fused" else bytes_per_sweep,
        bytes_per_sweep_path=bytes_per_sweep,
        roofline_s=(bytes_fused if mode == "fused" else bytes_per_sweep)
        / (HBM_GBPS * 1e9),
        stages_exec=stages_exec,
    )


@functools.lru_cache(maxsize=1024)
def _plan_cached(
    shape: tuple[int, int],
    dtype_name: str,
    stages: tuple,
    boundary: str,
    has_aux: bool,
) -> StencilPlan:
    return _build_plan(shape, dtype_name, stages, boundary, has_aux)


def _stage_key(stages: tuple) -> tuple[str, bool]:
    """A stable string for the stage descriptors plus whether it is stable
    across processes (opaque Python functors are not — their plans tune
    in-memory but are never persisted to the disk cache)."""
    parts, stable = [], True
    for d in stages:
        if d[0] == "linear":
            parts.append(f"lin{d[1]}{d[2]}")
        else:
            parts.append(f"functor@r{d[2]}")
            stable = False
    return ";".join(parts), stable


def _candidates(
    base: StencilPlan, shape: tuple, dtype_name: str, stages: tuple, has_aux: bool
) -> list[tune.Candidate]:
    """The stencil engine's search space: the row-panel neighborhood of
    the fused kernel, heuristic panel first.  The fused/per-sweep *mode*
    is deliberately not a candidate — per-sweep execution matches fused to
    tolerance, not bit-exactly, and tuning must never change results."""
    H, W = shape
    sl = sublanes(dtype_name)
    cands, seen = [], set()
    for br in neighborhood(base.block_rows, sl, H):
        if br in seen:
            continue
        seen.add(br)
        try:
            cp = _build_plan(shape, dtype_name, stages, base.boundary, has_aux, br)
        except ValueError:
            continue
        cands.append(
            tune.Candidate(
                label=f"panel{br}",
                params=(("block_rows", br),),
                cost_s=movement_cost_s(cp.bytes_moved, cp.grid),
            )
        )
    return cands


def _runner_factory(
    shape: tuple, dtype_name: str, stages: tuple, boundary: str, has_aux: bool
):
    """Measured-mode runner: run the fused pipeline at one candidate panel
    height on a deterministic sample grid."""

    def factory(cand: tune.Candidate):
        plan = _build_plan(
            shape, dtype_name, stages, boundary, has_aux,
            cand.param_dict()["block_rows"],
        )
        x = tune.sample_array(shape, dtype_name)
        aux = jnp.ones_like(x) if has_aux else None
        fn = jax.jit(
            lambda a: ops.stencil_program(
                a, plan.stages_exec, boundary=boundary,
                block_rows=plan.block_rows or None, aux=aux, fused=True,
            )
        )
        return lambda: fn(x)

    return factory


@functools.lru_cache(maxsize=1024)
def _plan_tuned_cached(
    shape: tuple[int, int],
    dtype_name: str,
    stages: tuple,
    boundary: str,
    has_aux: bool,
    mode: str,
) -> StencilPlan:
    base = _plan_cached(shape, dtype_name, stages, boundary, has_aux)
    if base.mode != "fused":
        return base  # reference route / empty grid: nothing to tune
    stage_key, stable = _stage_key(stages)
    choice = tune.select(
        "stencil",
        f"shape={shape}|dtype={dtype_name}|stages={stage_key}"
        f"|b={boundary}|aux={has_aux}",
        _candidates(base, shape, dtype_name, stages, has_aux),
        _runner_factory(shape, dtype_name, stages, boundary, has_aux),
        mode=mode,
        persist=stable,
    )
    br = choice.param_dict()["block_rows"]
    if br == base.block_rows:
        return base  # heuristic won: tuned and untuned plans are the SAME object
    return _build_plan(shape, dtype_name, stages, boundary, has_aux, br)


@dataclass(frozen=True)
class StencilProgram:
    """A compiled-together sequence of stencil stages (DESIGN.md §9).

    Built via :meth:`Stencil.then` / :meth:`Stencil.repeat` /
    :func:`functor_stage`; applying the program lowers every stage into ONE
    fused `pallas_call` with a ``sum(radius_i)``-row halo (temporal
    blocking), matching ``len(stages)`` sequential sweeps to fp32 tolerance.

    Example::

        jacobi = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)
        prog = jacobi.repeat(8)
        y = prog(x, boundary="reflect")         # one kernel, 8 sweeps
        plan = prog.compile(x.shape, x.dtype)   # inspect the lowering
        print(plan.describe())
    """

    stages: tuple[tuple, ...]

    @property
    def n_stages(self) -> int:
        """Number of stages (sweeps) in the program."""
        return len(self.stages)

    @property
    def total_radius(self) -> int:
        """Halo rows each panel loads: the sum of all stage radii."""
        return sum(_stage_exec(d)[1] for d in self.stages)

    def then(self, other: "Stencil | StencilProgram") -> "StencilProgram":
        """Append ``other`` (a stencil or a whole program) as later stage(s)."""
        if isinstance(other, Stencil):
            other = other.as_program()
        return StencilProgram(self.stages + other.stages)

    def repeat(self, k: int) -> "StencilProgram":
        """Repeat the whole program ``k`` times (``k >= 1``)."""
        if k < 1:
            raise ValueError(f"repeat wants k >= 1, got {k}")
        return StencilProgram(self.stages * k)

    def compile(
        self, shape: Sequence[int], dtype, *, boundary: str = "zero",
        has_aux: bool = False, tuned: bool | None = None,
    ) -> StencilPlan:
        """Plan (and cache) the lowering of this program for a grid.

        Repeated calls with equal arguments return the *identical*
        :class:`StencilPlan` object (lru cache keyed on shape, dtype, the
        stage descriptors, boundary, and aux-presence).  ``tuned=None``
        resolves from ``REPRO_TUNE``; ``tuned=True`` searches the row-panel
        neighborhood through the autotuner (DESIGN.md §11).
        """
        return plan_stencil(shape, dtype, self.stages, boundary, has_aux,
                            tuned=tuned)

    def shard(self, x: Array, *, mesh, axis: str, boundary: str = "zero") -> Array:
        """Run the program on a row-sharded grid with halo exchange.

        ``x`` is sharded ``P(axis, None)`` on ``mesh``; the distributed
        planner (`core/dist_plan.py`, DESIGN.md §10) partitions the program
        into k-blocks, swaps ``sum(radius_i)`` edge rows with the two mesh
        neighbors per block (one ``ppermute`` pair), and runs each block as
        ONE fused §9 kernel per shard.  Bit-identical to
        ``self(x, boundary=...)`` on a single device.

        Example::

            y = jacobi.repeat(8).shard(x, mesh=mesh, axis="data")
        """
        from repro.core import dist_plan

        return dist_plan.shard_stencil(
            self, x, mesh=mesh, axis=axis, boundary=boundary
        )

    def __call__(
        self, x: Array, *, boundary: str = "zero", aux: Array | None = None
    ) -> Array:
        """Run the program on a 2-D grid.

        ``aux`` optionally supplies a same-shape source grid; functor stages
        then receive it as ``functor(shift, src)`` where ``src()`` yields
        the aux band (e.g. the right-hand side of a Jacobi iteration).
        """
        if x.ndim != 2:
            raise ValueError(f"stencil programs want 2-D grids, got {x.shape}")
        if x.size == 0:
            return x
        plan = self.compile(
            x.shape, x.dtype, boundary=boundary, has_aux=aux is not None
        )
        return ops.stencil_program(
            x,
            plan.stages_exec,
            boundary=boundary,
            block_rows=plan.block_rows or None,
            aux=aux,
            fused=plan.mode == "fused",
        )


def functor_stage(functor: Callable, radius: int) -> StencilProgram:
    """One-stage program from an arbitrary trace-time functor.

    ``functor(shift)`` (or ``functor(shift, src)`` in aux programs) may be
    any jnp expression over ``shift(dy, dx)`` views — the paper's
    compile-time C++ functor, as a Python closure.

    Example::

        damp = functor_stage(lambda s: 0.5 * s(0, 0) + 0.5 * s(0, 1), 1)
        prog = damp.then(fd_laplacian(1)).repeat(2)
    """
    return StencilProgram((("functor", functor, int(radius)),))


def plan_stencil(
    shape: Sequence[int],
    dtype,
    stages: tuple,
    boundary: str = "zero",
    has_aux: bool = False,
    *,
    tuned: bool | None = None,
) -> StencilPlan:
    """Plan (and cache) the lowering of stage descriptors for a grid.

    The program-facing wrapper is :meth:`StencilProgram.compile`; this
    entry point exists for benchmarks and tests that build descriptor
    tuples directly.  ``tuned=None`` resolves from ``REPRO_TUNE``;
    ``tuned=True`` searches the fused kernel's row-panel neighborhood
    through the autotuner (DESIGN.md §11) — panel geometry only, so a
    tuned program's output stays bit-identical to the untuned one.
    """
    if boundary not in ref.BOUNDARY_PAD_MODES:
        raise ValueError(f"unknown boundary {boundary!r}; want one of {BOUNDARIES}")
    shape_t = tuple(int(s) for s in shape)
    if len(shape_t) != 2:
        raise ValueError(f"stencil plans want 2-D shapes, got {shape_t}")
    if tuned is None:
        tuned = tune.tune_default()
    key = (shape_t, jnp.dtype(dtype).name, tuple(stages), boundary, bool(has_aux))
    if not tuned:
        return _plan_cached(*key)
    return _plan_tuned_cached(*key, tune.resolve_mode())


def stencil_plan_cache_info():
    """Expose the plan-memo stats (tests / benchmarks)."""
    return _plan_cached.cache_info()


def fd_laplacian(order: int) -> Stencil:
    """2-D Laplacian, central differences of accuracy 2*order (paper Fig. 2
    orders I..IV).

    Example::

        y = fd_laplacian(2)(x)           # 9-point, 4th-order accurate
        y = fd_laplacian(1).repeat(4)(x) # 4 fused diffusion sweeps
    """
    offs, wts = ref.fd_stencil_offsets(order)
    return Stencil(tuple(offs), tuple(wts))


def box_blur(radius: int = 1) -> Stencil:
    """(2r+1)^2 box smoothing filter (the paper's image-filter example).

    Example::

        smooth = box_blur(1)             # 3x3 mean filter
        y = smooth(img, boundary="nearest")
    """
    offs = tuple(
        (dy, dx)
        for dy in range(-radius, radius + 1)
        for dx in range(-radius, radius + 1)
    )
    w = 1.0 / len(offs)
    return Stencil(offs, (w,) * len(offs))


def apply_functor(
    x: Array, functor: Callable, radius: int, *, boundary: str = "zero"
) -> Array:
    """Single sweep of an arbitrary (possibly non-linear) stencil functor.

    Example::

        def sharpen(shift):
            return 2.0 * shift(0, 0) - 0.25 * (
                shift(-1, 0) + shift(1, 0) + shift(0, -1) + shift(0, 1))
        y = apply_functor(img, sharpen, radius=1)

    For multi-sweep functor pipelines use :func:`functor_stage` and
    ``repeat`` — see ``repro.kernels.stencil2d.stencil2d_functor`` for the
    kernel underneath.
    """
    return ops.stencil2d_functor(x, functor, radius, boundary=boundary)


def conv1d_depthwise(x: Array, kernel: Array) -> Array:
    """Causal depthwise temporal conv over (B, S, D) with kernel (K, D) —
    the RG-LRU / recurrentgemma temporal-conv building block, expressed as
    a 1-D stencil (a degenerate §III-D stencil: all offsets (dy, 0)).

    out[b, s, d] = sum_k kernel[k, d] * x[b, s - (K-1) + k, d]
    """
    k = kernel.shape[0]
    pads = [(0, 0)] * x.ndim
    pads[-2] = (k - 1, 0)
    xp = jnp.pad(x, pads)
    out = jnp.zeros_like(x)
    s = x.shape[-2]
    for i in range(k):
        out = out + kernel[i] * jax.lax.dynamic_slice_in_dim(xp, i, s, axis=-2)
    return out
