"""Mesh-aware plan engines (DESIGN.md §10) — beyond-paper suite.

Three strategy comparisons on an 8-fake-device host mesh, with
bytes-on-wire accounting from the DistPlan cost model:

* sharded permute: comm-free local plan vs all_to_all redistribution vs
  the replicate (all_gather) fallback — same logical op, three wire costs;
* ``repeat(k)`` stencil: per-sweep execution (k ppermute pairs, k local
  kernels) vs the halo-blocked plan (one pair + one fused kernel per
  k-block) — same bytes on wire, k/blocks fewer collective latencies;
* MoE dispatch: dense (GSPMD one-hot einsums, XLA chooses collectives) vs
  expert-parallel sort (§4 blocked kernels around one all_to_all pair).

The harness process owns a single CPU device, so ``run()`` re-executes
this module in a subprocess with ``--xla_force_host_platform_device_count=8``
(the same recipe as ``make test-dist``) and adopts the child's records.
On this CPU container the timings are methodology stand-ins; the wire
bytes come from the plan cost model and are platform-independent.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_REC_PREFIX = "##REC "


def _child() -> None:
    """Runs inside the 8-device subprocess: measure and stream records."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks import common
    from repro import configs
    from repro.core import dist_plan as dp
    from repro.core import stencil as st
    from repro.launch.mesh import make_mesh_compat
    from repro.models import moe

    rng = np.random.default_rng(0)
    mesh = make_mesh_compat((8,), ("x",))
    mk = dp.mesh_key(mesh)
    # a second, 2-axis mesh: requesting the output on the OTHER axis has no
    # aligned collective, which is what exercises the replicate fallback
    mesh2 = make_mesh_compat((2, 4), ("a", "b"))

    # --- sharded permute: one op, three strategies -----------------------
    shape, dt = ((16, 16, 32) if common.smoke() else (64, 128, 256)), jnp.float32
    x = jnp.asarray(rng.standard_normal(shape), dt)
    gbytes = 2 * x.size * x.dtype.itemsize  # read + write, the §3 metric
    cases = [
        ("permute_local", mesh, P("x"), None),
        ("permute_a2a", mesh, P("x"), P(None, None, "x")),
        ("permute_replicate", mesh2, P("b"), P(None, None, "a")),
    ]
    for name, m, in_spec, out_spec in cases:
        plan = dp.plan_dist_rearrange(
            dp.mesh_key(m), in_spec,
            None if out_spec is None else out_spec, shape, dt, (1, 0, 2),
        )
        xs = jax.device_put(x, NamedSharding(m, in_spec))
        fn = jax.jit(
            lambda v, _m=m, _i=in_spec, _o=out_spec: dp.shard_permute(
                v, (1, 0, 2), mesh=_m, in_spec=_i, out_spec=_o
            )
        )
        secs = common.time_fn(fn, xs)
        print(common.row(
            name, secs, gbytes,
            note=f"[{plan.strategy}]",
            strategy=plan.strategy,
            bytes_on_wire=plan.bytes_on_wire,
            collectives=len(plan.collectives),
            plan=plan.describe(),
        ))

    # --- stencil: per-sweep vs halo-blocked ------------------------------
    jac = st.Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)
    g = jnp.asarray(
        rng.standard_normal((128, 64) if common.smoke() else (1024, 512)),
        jnp.float32,
    )
    gs = jax.device_put(g, NamedSharding(mesh, P("x", None)))
    k = 4 if common.smoke() else 8
    prog = jac.repeat(k)
    gb_grid = 2 * g.size * g.dtype.itemsize

    blocked = jax.jit(lambda v: prog.shard(v, mesh=mesh, axis="x"))
    plan_b = dp.plan_dist_stencil(mk, "x", g.shape, g.dtype, prog.stages, "zero")
    secs = common.time_fn(blocked, gs)
    print(common.row(
        f"stencil_halo_blocked_k{k}", secs, k * gb_grid,
        note=f"[{len(plan_b.detail)} blocks]",
        strategy=plan_b.strategy,
        bytes_on_wire=plan_b.bytes_on_wire,
        collectives=len(plan_b.collectives),
        plan=plan_b.describe(),
    ))

    sweep = jac.repeat(1)
    plan_s = dp.plan_dist_stencil(mk, "x", g.shape, g.dtype, sweep.stages, "zero")

    def per_sweep(v):
        for _ in range(k):
            v = sweep.shard(v, mesh=mesh, axis="x")
        return v

    secs = common.time_fn(jax.jit(per_sweep), gs)
    print(common.row(
        f"stencil_per_sweep_k{k}", secs, k * gb_grid,
        note=f"[{k} exchanges]",
        strategy="halo-per-sweep",
        bytes_on_wire=k * plan_s.bytes_on_wire,
        collectives=k * len(plan_s.collectives),
        plan=plan_s.describe(),
    ))

    # --- MoE: dense (GSPMD einsums) vs expert-parallel sort --------------
    cfg = configs.get_config("deepseek-moe-16b-smoke")
    seq_m = 8 if common.smoke() else 32
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    xm = jax.random.normal(
        jax.random.PRNGKey(1), (8, seq_m, cfg.d_model), jnp.float32
    ).astype(cfg.np_dtype)
    t = 8 * seq_m
    cap_ep = t // 8  # dropless per shard
    act_bytes = 2 * xm.size * xm.dtype.itemsize

    dense = jax.jit(lambda v: moe.moe_dense(p, cfg, v)[0])
    secs = common.time_fn(dense, xm)
    print(common.row(
        "moe_dense", secs, act_bytes,
        note="[one-hot einsum dispatch]",
        strategy="dense",
        collectives=-1,  # under GSPMD, XLA's choice — not plan-accounted
    ))

    plan_m = dp.plan_dist_moe(
        mk, "x", t, cfg.d_model, cfg.moe.n_experts, cap_ep, cfg.moe.top_k, xm.dtype
    )
    ep = jax.jit(
        lambda v: moe.moe_sort_ep(p, cfg, v, mesh=mesh, axis="x", capacity=cap_ep)[0]
    )
    secs = common.time_fn(ep, xm)
    print(common.row(
        "moe_sort_ep", secs, act_bytes,
        note=f"[{plan_m.strategy}]",
        strategy=plan_m.strategy,
        bytes_on_wire=plan_m.bytes_on_wire,
        collectives=len(plan_m.collectives),
        plan=plan_m.describe(),
    ))

    for rec in common.RECORDS:
        print(_REC_PREFIX + json.dumps(rec))


def run() -> list[str]:
    """Spawn the 8-device child, adopt its records, relay its CSV rows."""
    from benchmarks import common
    from repro.launch.mesh import fake_device_env

    env = {
        **os.environ,
        **fake_device_env(8),
        "REPRO_DIST_BENCH_CHILD": "1",
        "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    }
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_dist"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_dist child failed:\n{r.stderr[-2000:]}")
    out = []
    for line in r.stdout.splitlines():
        if line.startswith(_REC_PREFIX):
            common.RECORDS.append(json.loads(line[len(_REC_PREFIX):]))
        elif line.strip():
            out.append(line)
    return out


if __name__ == "__main__":
    if os.environ.get("REPRO_DIST_BENCH_CHILD") == "1":
        _child()
    else:
        for row in run():
            print(row)
