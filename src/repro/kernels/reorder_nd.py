"""Generic N-D reorder kernel (paper §III-B "Reorder Kernel"), TPU-native.

The paper's canonicalization — *every valid reorder reduces to batched 2-D
data movement in the plane of the fastest-changing input dim and the
fastest-changing output dim* — is kept intact.  What changes on TPU:

* CUDA stores the stride tables in **constant memory**; every thread reads
  them to compute its source address.  On TPU we go one better: block
  indices are computed *arithmetically in the scalar core* inside the
  BlockSpec ``index_map`` (mixed-radix decomposition of the linearized
  batch grid index, with radices baked in as compile-time constants).
  Zero memory traffic for metadata, and no 5-dim performance cliff — the
  paper's Table 2 shows 43 GB/s at 5-D because of metadata-lookup overhead;
  our index arithmetic is free relative to the DMAs it schedules.
* Exactly **two axes are blocked**: the input-fastest axis (lane dim of the
  load tile) and the axis that becomes output-fastest (lane dim of the
  store tile).  All other axes are batch.  Both DMAs therefore move full
  lane-aligned tiles — coalesced-on-both-sides, per the paper.
* If the permutation *preserves* the fastest axis ("copy mode"), the kernel
  degenerates to a blocked gather of contiguous rows — the paper's N-to-M
  case with preserved dim-0.

``permute_nd`` is the full-array form; ``reorder_window`` is the windowed
N->M form (paper §III-B), sharing the same grid builder with the (static)
window base folded into the input index map (DESIGN.md §6).

``perm`` uses numpy convention: ``out axis j  <-  in axis perm[j]``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (
    align_block,
    cdiv,
    force_interpret,
    plan_copy_tiles,
    plan_transpose_tiles,
    sublanes,
)


def _permute_kernel(perm, x_ref, o_ref):
    o_ref[...] = jnp.transpose(x_ref[...], perm)


def _dim_semantics(n: int):
    try:
        return pltpu.CompilerParams(dimension_semantics=(pltpu.ARBITRARY,) * n)
    except Exception:  # pragma: no cover
        return None


def _movement_axes(perm: tuple[int, ...]) -> tuple[int | None, int, bool]:
    """The two blocked axes of the movement plane: (r_in, c_in, transpose?).

    r_in is None at rank 1 (no second axis to block — a pure lane copy)."""
    N = len(perm)
    c_in = N - 1
    transpose_mode = perm[-1] != c_in
    if N < 2:
        return None, c_in, False
    r_in = perm[-1] if transpose_mode else perm[-2]
    return r_in, c_in, transpose_mode


def _align_block(block: int, offset: int) -> int:
    """Largest block <= ``block`` (by halving) that divides ``offset``, so a
    window base can ride in the index map as a whole number of blocks."""
    return align_block(block, offset)


def _reorder_call(
    x: jax.Array,
    perm: tuple[int, ...],
    base: tuple[int, ...],
    sizes: tuple[int, ...],
    br: int,
    bc: int,
    r_in: int | None,
    c_in: int,
    grid_order: str,
    interpret: bool,
) -> jax.Array:
    """Shared grid builder: ``transpose(x[base : base+sizes], perm)`` as one
    pallas_call.  Batch axes use unit blocks (any base offset is exact); the
    two blocked plane axes must have block-aligned bases (see callers)."""
    N = x.ndim
    W = sizes
    out_shape = tuple(W[p] for p in perm)

    blocks = [1] * N
    blocks[c_in] = bc
    if r_in is not None:
        blocks[r_in] = br
    nblocks = [cdiv(W[k], blocks[k]) for k in range(N)]
    offs = [base[k] // blocks[k] for k in range(N)]  # exact: blocks aligned

    plane = {c_in} if r_in is None else {r_in, c_in}
    if grid_order == "out":
        batch_in_axes = [p for p in perm if p not in plane]
    elif grid_order == "in":
        batch_in_axes = [k for k in range(N) if k not in plane]
    else:
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    batch_radix = [nblocks[a] for a in batch_in_axes]
    G = math.prod(batch_radix) if batch_radix else 1

    # mixed-radix weights: coordinate of batch axis a = (g // w[a]) % radix[a]
    weights: dict[int, int] = {}
    w = 1
    for a, r in zip(reversed(batch_in_axes), reversed(batch_radix)):
        weights[a] = w
        w *= r

    def win_coords(g, i, j):
        coords = []
        for k in range(N):
            if k == r_in:
                coords.append(i)
            elif k == c_in:
                coords.append(j)
            else:
                coords.append(lax.rem(g // weights[k], nblocks[k]))
        return coords

    def in_map(g, i, j):
        return tuple(c + offs[k] for k, c in enumerate(win_coords(g, i, j)))

    def out_map(g, i, j):
        c = win_coords(g, i, j)
        return tuple(c[p] for p in perm)

    in_block = tuple(blocks)
    out_block = tuple(blocks[p] for p in perm)
    grid_r = nblocks[r_in] if r_in is not None else 1

    params = _dim_semantics(3)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        functools.partial(_permute_kernel, perm),
        grid=(G, grid_r, nblocks[c_in]),
        in_specs=[pl.BlockSpec(in_block, in_map)],
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        interpret=interpret,
        **kwargs,
    )(x)


def _plan_blocks(
    perm: tuple[int, ...], sizes: tuple[int, ...], dtype
) -> tuple[int, int, int | None, int, bool]:
    """Tile the movement plane of ``perm`` over (window) ``sizes``."""
    r_in, c_in, transpose_mode = _movement_axes(perm)
    R = sizes[r_in] if r_in is not None else 1
    C = sizes[c_in]
    if transpose_mode:
        plan = plan_transpose_tiles(R, C, dtype)
    else:
        plan = plan_copy_tiles(R, C, dtype)
    return plan.block_r, plan.block_c, r_in, c_in, transpose_mode


@functools.partial(
    jax.jit,
    static_argnames=("perm", "block_r", "block_c", "grid_order", "interpret"),
)
def permute_nd(
    x: jax.Array,
    perm: tuple[int, ...],
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    grid_order: str = "out",
    interpret: bool | None = None,
) -> jax.Array:
    """General N-D permute: ``out = jnp.transpose(x, perm)`` as a tiled
    Pallas data-movement kernel.

    grid_order: 'out' walks batch blocks in output-linear order (stores are
    sequential in HBM), 'in' walks in input-linear order (loads sequential).
    This is the TPU analogue of the paper's block-scheduling policies.
    """
    N = x.ndim
    perm = tuple(int(p) for p in perm)
    if sorted(perm) != list(range(N)):
        raise ValueError(f"bad perm {perm} for rank {N}")
    if N == 0 or perm == tuple(range(N)):
        # identity: fall through to a plain copy (still a kernel-shaped op)
        return x + jnp.zeros((), x.dtype)

    pr, pc, r_in, c_in, _ = _plan_blocks(perm, x.shape, x.dtype)
    br = min(block_r or pr, x.shape[r_in]) if r_in is not None else 1
    bc = min(block_c or pc, x.shape[c_in])
    interpret = force_interpret() if interpret is None else interpret
    return _reorder_call(
        x, perm, (0,) * N, x.shape, br, bc, r_in, c_in, grid_order, interpret
    )


def _affine_body(perm_axes, out_block, rshift, x_ref, o_ref):
    """Kernel body for the affine route: reorder the loaded block into the
    output digit order, then (diagonal maps) apply the per-row modular lane
    shift while the lane digit is fully resident."""
    blk = jnp.transpose(x_ref[...], perm_axes).reshape(out_block)
    if rshift is not None:
        C, rot, sign, kind, weight, radix, br = rshift
        rows = max(blk.size // C, 1)
        plane = blk.reshape(rows, C)
        if kind == "row":
            coord = pl.program_id(1) * br + lax.broadcasted_iota(
                jnp.int32, (rows, 1), 0
            )
        else:  # batch digit: one coordinate per grid step
            coord = lax.rem(pl.program_id(0) // weight, radix)
        col = lax.broadcasted_iota(jnp.int32, (rows, C), 1)
        src_col = jnp.mod(col + rot + sign * coord, C)
        plane = jnp.take_along_axis(plane, src_col, axis=1)
        blk = plane.reshape(out_block)
    o_ref[...] = blk


@functools.partial(
    jax.jit,
    static_argnames=("amap", "block_r", "block_c", "grid_order", "interpret"),
)
def reorder_affine(
    x: jax.Array,
    amap,
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    grid_order: str = "out",
    interpret: bool | None = None,
) -> jax.Array:
    """Generalized reorder driven by an :class:`repro.core.affine.AffineMap`:
    ONE pallas_call computing ``out[o] = in[A·o + b]`` over mixed-radix
    digit spaces (window bases, per-digit rotations, and the diagonal skew).

    The map's closed-form derivation (``affine.derive``) picks the two
    blocked output digits; every other digit walks the batch grid with the
    per-digit mod-affine arithmetic evaluated *in the scalar core* inside
    the BlockSpec index_map — the affine generalization of ``permute_nd``'s
    mixed-radix decomposition, still zero memory traffic for metadata.  A
    skewed lane digit stays fully resident and is shifted in-kernel
    (`take_along_axis` over the lane axis).  Raises ValueError when the map
    has no single-pass lowering; dispatch falls back to the oracle."""
    from repro.core import affine as af  # lazy: affine imports tiling only

    ex = af.derive(amap, x.dtype, grid_order)
    m = ex.amap
    if m.n_out == 0 or m.n_in == 0:
        return jnp.zeros(m.out_digits, x.dtype)
    if ex.mode != "affine":
        # permutation class: the merged map is a plain (shape, perm) pair
        return permute_nd(
            x.reshape(m.in_digits), m.src,
            block_r=block_r or ex.block_r, block_c=block_c or ex.block_c,
            grid_order=grid_order, interpret=interpret,
        ).reshape(amap.out_digits)
    x = x.reshape(m.in_digits)
    outd, ind = m.out_digits, m.in_digits
    mo, ni = len(outd), len(ind)
    jr, jc = ex.jr, ex.jc
    R = outd[jr] if jr is not None else 1
    C = outd[jc]
    br = align_block(min(block_r or ex.block_r, R),
                     m.base[m.src[jr]]) if jr is not None else 1
    if ex.resident_skew:
        bc = C  # lane digit fully resident (shifted in-kernel)
    else:
        bc = align_block(min(block_c or ex.block_c, C), m.base[m.src[jc]])

    batch = [j for j in range(mo) if j != jr and j != jc]
    if grid_order == "in":
        batch.sort(key=lambda j: m.src[j])
    elif grid_order != "out":
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    # the skew source of every *batch* digit must itself be decodable from
    # the grid step: another batch digit, or a blocked digit at unit block
    for j in batch:
        k = m.skew[j]
        if k == jr and br != 1 or k == jc and bc != 1:
            raise ValueError("batch digit skewed off a blocked digit")
    gweights: dict[int, int] = {}
    w = 1
    for j in reversed(batch):
        gweights[j] = w
        w *= outd[j]
    G = w

    def coord(jdig, g, i, j):
        if jdig == jr:
            return i  # exact: br == 1 when used as a skew source
        if jdig == jc:
            return j
        return lax.rem(g // gweights[jdig], outd[jdig])

    def in_map(g, i, j):
        c = [m.base[d] for d in range(ni)]  # unmapped digits: pinned, block 1
        for jd in range(mo):
            d = m.src[jd]
            if jd == jr:
                c[d] = i + m.base[d] // br
            elif jd == jc:
                c[d] = 0 if ex.resident_skew else j + m.base[d] // bc
            else:
                o = coord(jd, g, i, j) + m.rot[jd]
                if m.skew[jd] >= 0:
                    o = o + m.skew_sign[jd] * coord(m.skew[jd], g, i, j)
                r = outd[jd]
                c[d] = m.base[d] + lax.rem(lax.rem(o, r) + r, r)
        return tuple(c)

    def out_map(g, i, j):
        return tuple(
            i if jd == jr else j if jd == jc else coord(jd, g, i, j)
            for jd in range(mo)
        )

    in_block = [1] * ni
    if jr is not None:
        in_block[m.src[jr]] = br
    in_block[m.src[jc]] = C if ex.resident_skew else bc
    out_block = [1] * mo
    if jr is not None:
        out_block[jr] = br
    out_block[jc] = C if ex.resident_skew else bc

    # in-block axes -> output digit order (trailing axes are unit window /
    # pinned digits, absorbed by the reshape)
    perm_axes = [m.src[jd] for jd in range(mo)]
    perm_axes += [d for d in range(ni) if d not in perm_axes]

    rshift = None
    if ex.resident_skew:
        k0 = m.skew[jc]
        if k0 == -1:  # rotation only: constant lane shift
            rshift = (C, m.rot[jc], 0, "batch", 1, 1, br)
        elif k0 == jr or k0 in gweights:
            kind = "row" if k0 == jr else "batch"
            rshift = (
                C, m.rot[jc], m.skew_sign[jc], kind,
                gweights.get(k0, 1), outd[k0], br,
            )
        else:
            raise ValueError("lane digit skewed off an undecodable digit")

    interpret = force_interpret() if interpret is None else interpret
    params = _dim_semantics(3)
    kwargs = {"compiler_params": params} if params is not None else {}
    out = pl.pallas_call(
        functools.partial(
            _affine_body, tuple(perm_axes), tuple(out_block), rshift
        ),
        grid=(G, cdiv(R, br) if jr is not None else 1, cdiv(C, bc)),
        in_specs=[pl.BlockSpec(tuple(in_block), in_map)],
        out_specs=pl.BlockSpec(tuple(out_block), out_map),
        out_shape=jax.ShapeDtypeStruct(outd, x.dtype),
        interpret=interpret,
        **kwargs,
    )(x)
    return out.reshape(amap.out_digits)


@functools.partial(
    jax.jit, static_argnames=("perm", "base", "sizes", "grid_order", "interpret")
)
def reorder_window(
    x: jax.Array,
    perm: tuple[int, ...],
    base: tuple[int, ...],
    sizes: tuple[int, ...],
    *,
    grid_order: str = "out",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused windowed N->M reorder (paper §III-B): one pallas_call computing
    ``transpose(x[base : base + sizes], perm)``.

    The window slice is *not* materialized — the static base offsets are
    folded into the input BlockSpec ``index_map`` (the TPU analogue of the
    paper's constant-memory metadata), so the windowed reorder is a single
    pass over HBM instead of slice-then-permute.  Blocked plane axes shrink
    their block (by halving) until the base offset is block-aligned; batch
    axes use unit blocks so any offset is exact.  A base so misaligned that
    the plane blocks would degrade below the sublane floor raises
    ValueError — dispatch then falls back to the two-pass form rather than
    issuing element-granular DMAs.
    """
    N = x.ndim
    perm = tuple(int(p) for p in perm)
    if sorted(perm) != list(range(N)):
        raise ValueError(f"bad perm {perm} for rank {N}")
    if len(base) != N or len(sizes) != N:
        raise ValueError(f"base/sizes must have rank {N}")
    for k in range(N):
        if not (0 <= base[k] and base[k] + sizes[k] <= x.shape[k]):
            raise ValueError(
                f"window [{base[k]}, {base[k]}+{sizes[k]}) exceeds axis {k} "
                f"of shape {x.shape}"
            )
    W = tuple(int(s) for s in sizes)

    pr, pc, r_in, c_in, _ = _plan_blocks(perm, W, x.dtype)
    br = _align_block(min(pr, W[r_in]), base[r_in]) if r_in is not None else 1
    bc = _align_block(min(pc, W[c_in]), base[c_in])
    # quality gate: misaligned bases shrink plane blocks; below the dtype's
    # sublane floor the fused pass would be slower than slice-then-permute
    sl = sublanes(x.dtype)
    floor_r = min(sl, W[r_in]) if r_in is not None else 1
    floor_c = min(sl, W[c_in])
    if (r_in is not None and br < floor_r) or bc < floor_c:
        raise ValueError(
            f"window base {base} too misaligned for fused blocks "
            f"({br}x{bc} < {floor_r}x{floor_c})"
        )
    interpret = force_interpret() if interpret is None else interpret
    return _reorder_call(
        x, perm, tuple(int(b) for b in base), W, br, bc, r_in, c_in,
        grid_order, interpret,
    )
