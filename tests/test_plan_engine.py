"""Plan-driven rearrangement engine: collapse -> route -> cache.

Covers the acceptance surface of the engine refactor:
* equivalence vs the jnp.transpose / jnp.stack oracles across ranks 1-6,
  every canonical mode, odd/unaligned shapes, and all supported dtypes
  (kernels execute via the Pallas interpreter, not the oracle);
* routing: the (B, S, H, D)-swap family hits the batched 2-D transpose
  kernel, collapse reduces canonical rank, the generic path stays as the
  fallback;
* the plan cache returns the identical plan object on repeated calls;
* each fused helper (split_heads / merge_heads / space_to_depth /
  interlace / windowed reorder_nm) compiles to exactly ONE pallas_call.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rearrange as rr
from repro.core.plan import plan_rearrange
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.int8]


def rand(shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(RNG.integers(-100, 100, shape), dtype)
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def n_pallas_calls(fn, *args) -> int:
    """Count pallas_call eqns anywhere in the traced jaxpr (incl. nested)."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call[")


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "shape", [(2, 6, 4, 8), (2, 4, 6, 8), (8, 512, 16, 64), (3, 5, 7, 2)]
)
def test_head_permute_routes_to_batched_transpose(shape):
    """(B, S, H, D) -> (0, 2, 1, 3) and (B, H, S, D) -> (0, 2, 1, 3) must
    hit the batched 2-D transpose kernel with a collapsed batch axis."""
    plan = plan_rearrange(shape, jnp.float32, (0, 2, 1, 3))
    assert plan.mode == "transpose"
    assert plan.kernel == "transpose2d_batched_vec"
    b, r, c, v = plan.exec_shape
    assert (b, r, c, v) == shape  # batch = B, plane = (S, H), vector = D


@pytest.mark.parametrize(
    "shape,perm,rank",
    [
        ((64, 4, 5), (1, 2, 0), 2),  # 3-cycle collapses to plain 2-D transpose
        ((4, 5, 6, 7), (2, 0, 1, 3), 3),  # (0,1) merge -> (1, 0, 2) swap family
        ((2, 3, 4, 5, 6), (0, 1, 3, 4, 2), 3),  # two merges
    ],
)
def test_collapse_reduces_rank(shape, perm, rank):
    plan = plan_rearrange(shape, jnp.float32, perm)
    assert len(plan.canonical_shape) == rank
    assert plan.mode == "transpose"


@pytest.mark.parametrize(
    "shape,perm,mode,kernel",
    [
        ((8, 16, 131), (0, 1, 2), "identity", "noop"),
        ((2, 1, 3), (1, 0, 2), "identity", "noop"),  # size-1 axis move
        ((5, 9), (1, 0), "transpose", "transpose2d_batched"),
        ((3, 40, 50), (0, 2, 1), "transpose", "transpose2d_batched"),
        ((2, 6, 4, 8), (0, 2, 1, 3), "transpose", "transpose2d_batched_vec"),
        ((4, 5, 6, 128), (2, 1, 0, 3), "copy", "reorder_nd"),
        ((2, 3, 4, 5, 6), (4, 2, 0, 3, 1), "reorder", "reorder_nd"),
    ],
)
def test_plan_modes(shape, perm, mode, kernel):
    plan = plan_rearrange(shape, jnp.float32, perm)
    assert plan.mode == mode
    assert plan.kernel == kernel


def test_plan_validates_inputs():
    with pytest.raises(ValueError, match="bad perm"):
        plan_rearrange((4, 8, 16), jnp.float32, (0, 0, 1))
    with pytest.raises(ValueError, match="grid_order"):
        plan_rearrange((4, 8, 16), jnp.float32, (2, 0, 1), grid_order="sideways")


@pytest.mark.parametrize(
    "shape,perm", [((2, 0, 3), (1, 0, 2)), ((0,), (0,)), ((4, 0), (1, 0))]
)
def test_zero_size_arrays_are_noop(shape, perm, pallas_interpret):
    plan = plan_rearrange(shape, jnp.float32, perm)
    assert plan.mode == "identity" and plan.bytes_moved == 0
    got = ops.permute(jnp.ones(shape, jnp.float32), perm)
    assert got.shape == jnp.transpose(jnp.ones(shape, jnp.float32), perm).shape


def test_plan_cache_returns_identical_object():
    a = plan_rearrange((4, 8, 16, 32), jnp.bfloat16, (0, 2, 1, 3))
    b = plan_rearrange((4, 8, 16, 32), jnp.bfloat16, (0, 2, 1, 3))
    assert a is b
    # dtype spellings normalize to the same key
    c = plan_rearrange((4, 8, 16, 32), np.dtype("bfloat16"), (0, 2, 1, 3))
    assert c is a
    # grid_order is part of the key
    d = plan_rearrange((4, 8, 16, 32), jnp.bfloat16, (0, 2, 1, 3), grid_order="in")
    assert d is not a and d.grid_order == "in"


# ---------------------------------------------------------------------------
# equivalence vs jnp.transpose, every mode / rank 1-6 / odd shapes / dtypes
# ---------------------------------------------------------------------------

CASES = [
    ((7,), (0,)),  # rank 1 identity
    ((5, 9), (1, 0)),  # odd 2-D transpose
    ((3, 40, 257), (0, 2, 1)),  # batched transpose, unaligned cols
    ((64, 4, 5), (1, 2, 0)),  # collapses to 2-D transpose
    ((2, 1, 3), (1, 0, 2)),  # identity via size-1 move
    ((6, 24, 136), (2, 1, 0)),  # generic reorder
    ((4, 5, 6, 130), (2, 1, 0, 3)),  # copy mode, unaligned vector tail
    ((2, 6, 4, 8), (0, 2, 1, 3)),  # vec batched transpose
    ((3, 4, 5, 6, 7), (4, 2, 0, 3, 1)),  # rank 5 generic
    ((2, 3, 4, 5, 6, 7), (5, 0, 4, 1, 3, 2)),  # rank 6 generic
    ((2, 3, 4, 5, 6, 7), (0, 1, 3, 2, 4, 5)),  # rank 6 swap family
]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape,perm", CASES)
def test_engine_matches_transpose_oracle(shape, perm, dtype, pallas_interpret):
    x = rand(shape, dtype)
    got = ops.permute(x, perm)
    want = jnp.transpose(x, perm)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("grid_order", ["in", "out"])
def test_engine_grid_order_policies(grid_order, pallas_interpret):
    x = rand((4, 5, 6, 64), jnp.float32)
    got = ops.permute(x, (2, 0, 3, 1), grid_order=grid_order)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(x, (2, 0, 3, 1)))
    )


# ---------------------------------------------------------------------------
# fused helpers: exactly one pallas_call each
# ---------------------------------------------------------------------------


def test_split_heads_single_kernel(pallas_interpret):
    x = rand((2, 32, 16 * 8), jnp.float32)
    assert n_pallas_calls(lambda t: rr.split_heads(t, 16), x) == 1
    got = rr.split_heads(x, 16)
    want = jnp.transpose(x.reshape(2, 32, 16, 8), (0, 2, 1, 3))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_merge_heads_single_kernel(pallas_interpret):
    x = rand((2, 16, 32, 8), jnp.float32)
    assert n_pallas_calls(rr.merge_heads, x) == 1
    got = rr.merge_heads(x)
    want = jnp.transpose(x, (0, 2, 1, 3)).reshape(2, 32, 16 * 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # round trip
    back = rr.split_heads(got, 16)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_space_to_depth_single_kernel(pallas_interpret):
    img = rand((2, 8, 12, 6), jnp.float32)
    assert n_pallas_calls(lambda t: rr.space_to_depth(t, 2), img) == 1
    got = rr.space_to_depth(img, 2)
    want = (
        img.reshape(2, 4, 2, 6, 2, 6)
        .transpose(0, 1, 3, 2, 4, 5)
        .reshape(2, 4, 6, 2 * 2 * 6)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [2, 3, 4])
def test_interlace_nd_single_kernel_vs_stack_oracle(n, pallas_interpret):
    arrays = [rand((3, 4, 256), jnp.float32) for _ in range(n)]
    assert n_pallas_calls(lambda *a: rr.interlace(list(a)), *arrays) == 1
    il = rr.interlace(arrays)
    want = jnp.stack(arrays, axis=-1).reshape(3, 4, 256 * n)
    np.testing.assert_array_equal(np.asarray(il), np.asarray(want))
    back = rr.deinterlace(il, n)
    assert n_pallas_calls(lambda t: rr.deinterlace(t, n)[0], il) == 1
    for a, b in zip(arrays, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused windowed N->M reorder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "base,sizes,perm",
    [
        ((3, 7, 5, 11), (1, 30, 20, 1), (2, 1)),  # odd offsets, dropped dims
        ((0, 0, 0, 0), (6, 50, 1, 32), (3, 0, 1)),  # aligned, keep 3 of 4
        ((2, 0, 0, 0), (1, 50, 40, 32), (1, 3, 2)),  # full window on kept axes
    ],
)
def test_reorder_nm_windowed_fused(base, sizes, perm, pallas_interpret):
    x = rand((6, 50, 40, 32), jnp.float32)
    got = ops.reorder_nm(x, perm, base=base, sizes=sizes)
    want = ref.reorder_nm(x, perm, base=list(base), sizes=list(sizes))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    jaxpr = str(
        jax.make_jaxpr(lambda t: ops.reorder_nm(t, perm, base=base, sizes=sizes))(x)
    )
    assert jaxpr.count("pallas_call[") == 1
    assert "dynamic_slice" not in jaxpr  # the slice rides in the index_map


def test_reorder_nm_misaligned_base_falls_back_correctly(pallas_interpret):
    """A base too misaligned for fused blocks must still be correct (the
    dispatch falls back to slice-then-permute instead of 1-wide DMAs)."""
    x = rand((4, 64, 200), jnp.float32)
    base, sizes, perm = (1, 3, 7), (2, 40, 150), (2, 1, 0)
    got = ops.reorder_nm(x, perm, base=base, sizes=sizes)
    want = ref.reorder_nm(x, perm, base=list(base), sizes=list(sizes))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_reorder_nm_1d_window(pallas_interpret):
    x = rand((256,), jnp.float32)
    got = ops.reorder_nm(x, (0,), base=(64,), sizes=(128,))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x)[64:192])


def test_interlace_zero_length_falls_back(pallas_interpret):
    a = jnp.zeros((3, 0), jnp.float32)
    out = ops.interlace([a, a])
    assert out.shape == (3, 0)
    backs = ops.deinterlace(out, 2)
    assert all(b.shape == (3, 0) for b in backs)


def test_interlace_rejects_mismatched_shapes(pallas_interpret):
    """Same element count but different shapes must error (via the oracle),
    not silently interleave garbage."""
    a = rand((2, 64), jnp.float32)
    b = rand((4, 32), jnp.float32)
    with pytest.raises(Exception):
        ops.interlace([a, b])


def test_reorder_nm_rejects_wide_dropped_axis(pallas_interpret):
    x = rand((4, 8, 16), jnp.float32)
    with pytest.raises(ValueError, match="window size 1"):
        ops.reorder_nm(x, (2, 1), base=(0, 0, 0), sizes=(3, 8, 16))


def test_reorder_nm_full_rank_is_plain_permute(pallas_interpret):
    x = rand((4, 8, 16), jnp.float32)
    got = ops.reorder_nm(x, (2, 0, 1))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(x, (2, 0, 1)))
    )
