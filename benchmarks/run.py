"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only copy,permute,...] [--smoke]

Prints ``name,us_per_call,derived`` CSV per row (derived = achieved GB/s
and fraction of host memcpy — the paper's normalization), and writes the
machine-readable record stream to ``BENCH_rearrange.json`` (op name,
achieved GB/s, fraction of memcpy, plan mode) so the perf trajectory is
tracked across PRs.  The stencil suite's rows (fused vs per-sweep plan
engine comparison) are additionally written to ``BENCH_stencil.json``,
the MoE dispatch suite's rows (dense vs rowwise-sort vs fused-sort
IndexPlan comparison) to ``BENCH_moe.json``, the mesh-aware suite's
rows (DistPlan strategies with bytes-on-wire accounting, run on 8 forced
host devices in a subprocess) to ``BENCH_dist.json``, and the serving
suite's rows (split-KV vs one-shot decode, ragged vs bucket prefill, the
multi-tenant trace with tokens/s and p50/p99 per-token latency) to
``BENCH_serve.json``, and the training suite's rows (flash fwd/bwd and
FFN phase rooflines, monolithic vs blockwise-parallel train step with
tokens/s/device) to ``BENCH_train.json``.

The head-permute and stencil suites also report the autotuned plan next
to the heuristic one (``plan_source`` field, DESIGN.md §11) so tuned and
heuristic measured paths are tracked side by side.

``--smoke`` runs every suite on tiny deterministic shapes with reduced
timing loops (interpret-safe), and — unless a ``--json*`` path is given
explicitly — suppresses the JSON artifacts so a smoke run can never
overwrite the committed bare-metal ``BENCH_*.json`` numbers.  This is
what ``tools/check_bench.py`` (``make bench-check``) replays on every PR.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import common

SUITES = [
    ("copy", "benchmarks.bench_copy", "Fig. 1 read/write kernels"),
    ("permute", "benchmarks.bench_permute", "Table 1 3D permute"),
    ("reorder", "benchmarks.bench_reorder", "Table 2 generic reorder"),
    ("interlace", "benchmarks.bench_interlace", "Table 3 interlace/deinterlace"),
    ("stencil", "benchmarks.bench_stencil", "Fig. 2/Table 4 2D FD stencil"),
    ("moe_dispatch", "benchmarks.bench_moe_dispatch", "beyond-paper MoE dispatch"),
    ("dist", "benchmarks.bench_dist", "beyond-paper mesh-aware engines (8 fake devices)"),
    ("serve", "benchmarks.bench_serve", "beyond-paper serving engine (split-KV decode, ragged prefill)"),
    ("train", "benchmarks.bench_train", "beyond-paper training path (flash bwd, blockwise blocks)"),
    ("roofline", "benchmarks.bench_roofline", "dry-run roofline table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny deterministic shapes, reduced timing loops, no JSON "
        "unless a --json* path is given explicitly",
    )
    ap.add_argument(
        "--json", default=None, help="machine-readable output path"
    )
    ap.add_argument(
        "--json-stencil",
        default=None,
        help="output path for the stencil suite's plan-engine rows",
    )
    ap.add_argument(
        "--json-moe",
        default=None,
        help="output path for the MoE dispatch suite's plan-engine rows",
    )
    ap.add_argument(
        "--json-dist",
        default=None,
        help="output path for the mesh-aware suite's strategy-comparison rows",
    )
    ap.add_argument(
        "--json-serve",
        default=None,
        help="output path for the serving suite's decode/prefill/trace rows",
    )
    ap.add_argument(
        "--json-train",
        default=None,
        help="output path for the training suite's phase-roofline and "
        "train-step rows",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke:
        common.SMOKE = True
        os.environ["REPRO_BENCH_SMOKE"] = "1"  # reaches the dist subprocess
    defaults = {
        "json": "BENCH_rearrange.json",
        "json_stencil": "BENCH_stencil.json",
        "json_moe": "BENCH_moe.json",
        "json_dist": "BENCH_dist.json",
        "json_serve": "BENCH_serve.json",
        "json_train": "BENCH_train.json",
    }
    for attr, path in defaults.items():
        if getattr(args, attr) is None:
            # smoke runs never overwrite the committed bare-metal numbers
            setattr(args, attr, "" if args.smoke else path)

    common.RECORDS.clear()
    print("name,us_per_call,derived")
    for key, module, title in SUITES:
        if only and key not in only:
            continue
        t0 = time.time()
        print(f"# === {title} ({module}) ===", flush=True)
        n_before = len(common.RECORDS)
        try:
            mod = __import__(module, fromlist=["run"])
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"# {key} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            print(f"{key},error,{type(e).__name__}")
        for rec in common.RECORDS[n_before:]:
            rec.setdefault("suite", key)
        print(f"# ({time.time()-t0:.1f}s)", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"memcpy_gbps": round(common.memcpy_gbps(), 2), "rows": common.RECORDS},
                f,
                indent=2,
            )
            f.write("\n")
        print(f"# wrote {args.json} ({len(common.RECORDS)} rows)", flush=True)

    # per-engine comparisons get their own tracked artifacts
    for suite, path in (
        ("stencil", args.json_stencil),
        ("moe_dispatch", args.json_moe),
        ("dist", args.json_dist),
        ("serve", args.json_serve),
        ("train", args.json_train),
    ):
        suite_rows = [r for r in common.RECORDS if r.get("suite") == suite]
        if suite_rows and path:
            with open(path, "w") as f:
                json.dump(
                    {"memcpy_gbps": round(common.memcpy_gbps(), 2), "rows": suite_rows},
                    f,
                    indent=2,
                )
                f.write("\n")
            print(f"# wrote {path} ({len(suite_rows)} rows)", flush=True)


if __name__ == "__main__":
    main()
