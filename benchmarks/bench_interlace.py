"""Paper Table 3: interlace / de-interlace for n = 4..9 arrays."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, smoke, time_fn
from repro.kernels import ops


def run() -> list[str]:
    out = []
    rng = np.random.default_rng(0)
    # 32 MB per array (scaled from the paper's 0.27 GB); 256 KB in smoke
    length = 64 * 1024 if smoke() else 8 * 1024 * 1024
    for n in (4, 5) if smoke() else (4, 5, 6, 7, 8, 9):
        arrays = [
            jnp.asarray(rng.standard_normal(length), jnp.float32) for _ in range(n)
        ]
        nbytes = 2 * sum(a.nbytes for a in arrays)
        il = jax.jit(lambda *a: ops.interlace(list(a)))
        t = time_fn(il, *arrays)
        out.append(row(f"interlace_n{n}", t, nbytes))
        merged = il(*arrays)
        dl = jax.jit(lambda x, n=n: ops.deinterlace(x, n))
        t = time_fn(dl, merged)
        out.append(row(f"deinterlace_n{n}", t, nbytes))
    return out
