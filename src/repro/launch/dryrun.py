"""Multi-pod dry-run: lower + compile every (arch x shape cell) on the
production meshes; derive the three-term roofline per cell.

Two lowerings per cell (see EXPERIMENTS.md §Dry-run for why):

  1. *scan-mode* — the production config exactly as the trainer runs it
     (scan over layers, grad accumulation).  Proves the sharding compiles
     and gives ``memory_analysis()`` (XLA sizes loop buffers correctly).
  2. *analysis-mode* — XLA's ``cost_analysis()`` counts a while body ONCE,
     so roofline terms come from scan-unrolled reduced-unit lowerings:
     per-stage unit cost = cost(2 units) - cost(1 unit), and
     total = base + sum_i (count_i - 1) * unit_i  (exact: scan bodies are
     homogeneous).  For the ssm family (per-timestep scans) costs are
     additionally linear-extrapolated from two sequence lengths.

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both

Results land in runs/dryrun/<mesh>/<arch>--<cell>.json (resumable).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# TPU-faithful HLO: keep bf16-in/f32-out dots in the lowering (we only
# lower+compile here; nothing executes on the CPU backend).  The 512-device
# init must precede any jax import, which is why these lines sit above the
# import block.
os.environ.setdefault("REPRO_BF16_DOT", "1")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import specs  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.utils import hlo as hlolib  # noqa: E402
from repro.utils import roofline as rl  # noqa: E402

OUT_DIR = Path(os.environ.get("REPRO_DRYRUN_DIR", "runs/dryrun"))
TRAIN_ACCUM = int(os.environ.get("REPRO_DRYRUN_ACCUM", "8"))


def _cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict (jax 0.4.x returns [dict])."""
    cost = compiled.cost_analysis()
    return cost[0] if isinstance(cost, list) else cost


def lower_cell(cfg, cell, mesh, *, accum_steps: int = 1):
    """Lower + compile one (config, shape cell) on ``mesh``; returns the
    compiled executable (nothing executes — CPU backend, abstract inputs)."""
    step = specs.make_step(cfg, cell, mesh, adamw.OptConfig(), accum_steps=accum_steps)
    inputs = specs.input_specs(cfg, cell)
    in_sh = specs.input_shardings(cfg, cell, mesh)
    pshard = specs.param_shardings(cfg, mesh)
    params_abs = tf.abstract_params(cfg)

    with meshlib.set_mesh_compat(mesh):
        if cell.kind == "train":
            oshard = specs.opt_shardings(cfg, mesh)
            opt_abs = jax.eval_shape(adamw.init, params_abs)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, in_sh),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            return jitted.lower(params_abs, opt_abs, inputs)
        if cell.kind == "prefill":
            jitted = jax.jit(step, in_shardings=(pshard, in_sh))
            return jitted.lower(params_abs, inputs)
        jitted = jax.jit(step, in_shardings=(pshard, in_sh), donate_argnums=(1,))
        return jitted.lower(params_abs, inputs)


# ---------------------------------------------------------------------------
# analysis mode (roofline terms)
# ---------------------------------------------------------------------------


def _reduced(cfg, stage_counts, enc_layers):
    plan = tuple(
        (unit, c) for (unit, _), c in zip(cfg.layer_plan(), stage_counts)
    )
    n_layers = sum(len(u) * c for u, c in plan)
    return cfg.with_(
        explicit_plan=plan, n_layers=n_layers, encoder_layers=enc_layers
    )


def _cost_triple(cfg, cell, mesh) -> np.ndarray:
    lowered = lower_cell(cfg, cell, mesh, accum_steps=1)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    coll = hlolib.collective_stats(compiled.as_text())
    return np.array(
        [
            float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]),
        ]
    )


def analysis_cost(cfg, cell, mesh) -> dict:
    """Per-device (flops, bytes, collective bytes) via unrolled marginals."""
    os.environ["REPRO_UNROLL_SCANS"] = "1"
    try:
        cfg_a = cfg.with_(attn_chunk=max(cfg.attn_chunk, 2048))
        plan = cfg.layer_plan()
        counts = [c for _, c in plan]
        enc = cfg.encoder_layers
        seq_marginal = cfg.family == "ssm" and cell.kind in ("train", "prefill")

        def costs_at(cell_v) -> tuple[np.ndarray, list[np.ndarray], np.ndarray | None]:
            base_cfg = _reduced(cfg_a, [1] * len(counts), min(enc, 1))
            base = _cost_triple(base_cfg, cell_v, mesh)
            units = []
            for i, cnt in enumerate(counts):
                if cnt > 1:
                    sc = [2 if j == i else 1 for j in range(len(counts))]
                    v = _cost_triple(_reduced(cfg_a, sc, min(enc, 1)), cell_v, mesh)
                    units.append(v - base)
                else:
                    units.append(np.zeros(3))
            enc_unit = None
            if enc > 1:
                v = _cost_triple(
                    _reduced(cfg_a, [1] * len(counts), 2), cell_v, mesh
                )
                enc_unit = v - base
            return base, units, enc_unit

        if seq_marginal:
            # recurrent costs are exactly linear in S, so the marginal can
            # be taken at tiny S (unrolling 64+ timesteps explodes XLA
            # compile time; 8/16 compile in seconds and extrapolate exactly)
            s1, s2 = 8, 16
            c1 = dataclasses.replace(cell, seq_len=s1)
            c2 = dataclasses.replace(cell, seq_len=s2)
            b1, u1, e1 = costs_at(c1)
            b2, u2, e2 = costs_at(c2)
            s = cell.seq_len

            def extrap(a1, a2):
                slope = (a2 - a1) / (s2 - s1)
                return a1 + slope * (s - s1)

            base = extrap(b1, b2)
            units = [extrap(x, y) for x, y in zip(u1, u2)]
            enc_unit = extrap(e1, e2) if e1 is not None else None
        else:
            base, units, enc_unit = costs_at(cell)

        total = base.copy()
        for cnt, u in zip(counts, units):
            total += (cnt - 1) * u
        if enc_unit is not None:
            total += (enc - 1) * enc_unit
        return {
            "flops_per_dev": float(total[0]),
            "bytes_per_dev": float(total[1]),
            "coll_bytes_per_dev": float(total[2]),
            "base": base.tolist(),
            "per_stage_unit": [u.tolist() for u in units],
            "method": "unrolled-marginal"
            + ("+seq-extrapolated" if seq_marginal else ""),
        }
    finally:
        os.environ["REPRO_UNROLL_SCANS"] = "0"


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, cell_name: str, multi_pod: bool, *, force: bool = False,
             analysis: bool = True) -> dict:
    """Dry-run one cell end to end (lower, compile, roofline) and persist
    the record to runs/dryrun/ — existing records short-circuit (resume)."""
    mesh_name = "multi" if multi_pod else "single"
    out_path = OUT_DIR / mesh_name / f"{arch}--{cell_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    cfg = configs.get_config(arch)
    cell = configs.SHAPE_CELLS[cell_name]
    applicable = [c.name for c in configs.cells_for(cfg)]
    rec: dict = {
        "arch": arch,
        "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "timestamp": time.time(),
    }
    if cell_name not in applicable:
        rec["status"] = "skipped"
        rec["reason"] = (
            "long_500k requires sub-quadratic attention; "
            f"{arch} is full-attention (see DESIGN.md §7)"
        )
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    try:
        # phase 1: production (scan-mode) compile — memory + schedule proof
        accum = TRAIN_ACCUM if cell.kind == "train" else 1
        t0 = time.time()
        lowered = lower_cell(cfg, cell, mesh, accum_steps=accum)
        compiled = lowered.compile()
        t1 = time.time()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        rec.update(
            status="ok",
            compile_s=round(t1 - t0, 1),
            accum_steps=accum,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            scan_mode_cost={
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "note": "while bodies counted once; see analysis for true terms",
            },
            collectives_scan_mode=hlolib.collective_stats(compiled.as_text()),
        )

        # phase 2: roofline terms (single-pod only, per spec)
        if analysis and not multi_pod:
            t2 = time.time()
            ana = analysis_cost(cfg, cell, mesh)
            rec["analysis"] = ana
            rec["analysis_s"] = round(time.time() - t2, 1)
            roof = rl.Roofline(
                flops_per_dev=ana["flops_per_dev"],
                bytes_per_dev=ana["bytes_per_dev"],
                coll_bytes_per_dev=ana["coll_bytes_per_dev"],
                model_flops_global=rl.model_flops(cfg, cell),
                n_chips=mesh.size,
            )
            rec["roofline"] = roof.to_dict()
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    """CLI driver: dry-run every requested (arch, cell, mesh) combination."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-analysis", action="store_true")
    args = ap.parse_args()

    archs = list(configs.ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    cells = list(configs.SHAPE_CELLS) if args.cell == "all" else args.cell.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for multi in meshes:
        for arch in archs:
            for cell in cells:
                t0 = time.time()
                rec = run_cell(
                    arch, cell, multi, force=args.force,
                    analysis=not args.no_analysis,
                )
                status = rec.get("status")
                extra = ""
                if status == "ok" and "roofline" in rec:
                    r = rec["roofline"]
                    extra = (
                        f" bottleneck={r['bottleneck']}"
                        f" step={r['step_time_s']*1e3:.1f}ms"
                        f" mfu_bound={r['mfu_bound']:.2f}"
                    )
                elif status == "error":
                    extra = " " + rec.get("error", "")[:160]
                print(
                    f"[{'multi' if multi else 'single'}] {arch} x {cell}: "
                    f"{status}{extra} ({time.time()-t0:.0f}s)",
                    flush=True,
                )


if __name__ == "__main__":
    main()
