"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE: 2 shared +
64 routed experts, top-6, expert dim 1408; layer 0 is a dense FFN
(d_ff_dense=10944 per the released checkpoint)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # routed expert dim (assigned spec)
    vocab=102400,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        capacity_factor=1.25,
        dispatch="dense",
        shard="expert",  # 64 experts / 16-way model axis = 4 per shard
    ),
    d_ff_dense=10944,
    explicit_plan=((("attn_dense",), 1), (("attn_moe",), 27)),
    source="arXiv:2401.06066 (hf: deepseek-ai/deepseek-moe-16b-base)",
)
