"""2-D lid-driven cavity flow solver on the stencil library — the paper's
own application demo (§IV / ref [12], their Navier-Stokes poster).

Vorticity-streamfunction formulation:
    w_t + u w_x + v w_y = (1/Re) lap(w)
    lap(psi) = -w ;  u = psi_y ; v = -psi_x
Jacobi iterations for the Poisson solve, central differences for
advection/diffusion — every operator is a library Stencil, and the whole
Jacobi sweep loop is ONE fused ``repeat(k)`` stencil program (DESIGN.md §9):
k HBM round trips collapse into a single temporally-blocked kernel.

  PYTHONPATH=src python examples/cfd_cavity.py [--n 128 --re 100 --steps 200]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import Stencil, functor_stage

# library stencils (paper §III-D objects)
LAP = Stencil(((0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)), (-4.0, 1.0, 1.0, 1.0, 1.0))
DDX = Stencil(((0, 1), (0, -1)), (0.5, -0.5))
DDY = Stencil(((1, 0), (-1, 0)), (0.5, -0.5))


def _jacobi_with_source(shift, src):
    # one Jacobi sweep of lap(psi) = -w: psi <- avg(neighbors) + (h^2/4) w;
    # src() is the precomputed right-hand side riding as the aux operand
    return 0.25 * (shift(1, 0) + shift(-1, 0) + shift(0, 1) + shift(0, -1)) + src()


POISSON_SWEEP = functor_stage(_jacobi_with_source, 1)


def step(w, psi, *, re: float, dt: float, h: float, u_lid: float, jacobi_iters: int):
    # Poisson: lap(psi) = -w.  Dirichlet psi=0 on the walls == solving on
    # the interior view with a zero boundary condition, so the whole
    # k-sweep Jacobi loop is one fused repeat(k) program (one pallas_call
    # on the kernel path) instead of k HBM round trips.
    rhs = (h * h / 4.0) * w[1:-1, 1:-1]
    psi_int = POISSON_SWEEP.repeat(jacobi_iters)(
        psi[1:-1, 1:-1], boundary="zero", aux=rhs
    )
    psi = jnp.pad(psi_int, 1)

    u = DDY(psi) / h
    v = -DDX(psi) / h

    # wall vorticity (Thom's formula); lid moves at u_lid along the top row
    w = w.at[-1, :].set(-2.0 * psi[-2, :] / (h * h) - 2.0 * u_lid / h)
    w = w.at[0, :].set(-2.0 * psi[1, :] / (h * h))
    w = w.at[:, 0].set(-2.0 * psi[:, 1] / (h * h))
    w = w.at[:, -1].set(-2.0 * psi[:, -2] / (h * h))

    adv = u * DDX(w) / h + v * DDY(w) / h
    diff = LAP(w) / (h * h)
    w_new = w + dt * (diff / re - adv)
    # keep walls fixed this step (recomputed next step)
    w_new = (
        w_new.at[0, :].set(w[0, :]).at[-1, :].set(w[-1, :])
        .at[:, 0].set(w[:, 0]).at[:, -1].set(w[:, -1])
    )
    return w_new, psi


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--re", type=float, default=100.0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--jacobi", type=int, default=30)
    args = ap.parse_args()

    n = args.n
    h = 1.0 / (n - 1)
    dt = 0.2 * h * h * args.re  # stable explicit step
    w = jnp.zeros((n, n), jnp.float32)
    psi = jnp.zeros((n, n), jnp.float32)

    plan = POISSON_SWEEP.repeat(args.jacobi).compile(
        (n - 2, n - 2), jnp.float32, has_aux=True
    )
    print("poisson plan:", plan.describe())

    stepper = jax.jit(
        lambda w, psi: step(
            w, psi, re=args.re, dt=dt, h=h, u_lid=1.0, jacobi_iters=args.jacobi
        )
    )
    w, psi = stepper(w, psi)  # compile
    t0 = time.time()
    for _ in range(args.steps):
        w, psi = stepper(w, psi)
    jax.block_until_ready(w)
    dt_wall = time.time() - t0

    # bandwidth accounting: each step moves ~ (jacobi*3 + 8) n^2 arrays
    arrays_per_step = args.jacobi * 3 + 10
    gb = args.steps * arrays_per_step * n * n * 4 / 1e9
    print(f"cavity {n}x{n} Re={args.re}: {args.steps} steps in {dt_wall:.2f}s "
          f"(~{gb/dt_wall:.2f} GB/s effective)")

    psi_np = np.asarray(psi)
    ci, cj = np.unravel_index(np.argmin(psi_np), psi_np.shape)
    print(f"primary vortex: psi_min={psi_np.min():.5f} at "
          f"(y={ci/(n-1):.2f}, x={cj/(n-1):.2f})  [Ghia Re=100 ref: ~(0.74, 0.62)]")
    assert psi_np.min() < -1e-3, "no vortex formed — solver broken"
    assert np.isfinite(psi_np).all()


if __name__ == "__main__":
    main()
