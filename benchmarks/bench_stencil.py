"""Paper Fig. 2 / Table 4: 2-D FD stencil, orders I..IV, 4096^2 fp32."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import stencil as st


def run() -> list[str]:
    out = []
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4096, 4096)), jnp.float32)
    nbytes = 2 * x.size * 4  # in + out (the stencil reads each cell ~1x via halo reuse)
    for order in (1, 2, 3, 4):
        s = st.fd_laplacian(order)
        fn = jax.jit(lambda a, s=s: s(a))
        t = time_fn(fn, x)
        out.append(row(f"fd_stencil_order{order}", t, nbytes, f"[{len(s.offsets)}pt]"))
    # generic functor variant (paper's template mechanism): box blur
    blur = st.box_blur(1)
    t = time_fn(jax.jit(lambda a: blur(a)), x)
    out.append(row("box_blur_3x3", t, nbytes))
    return out
