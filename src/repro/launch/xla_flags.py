"""XLA inference-flag presets for the serving engine (DESIGN.md §12).

Serving is latency-bound and memory-bound — a different compiler regime
from the training launchers — so the engine ships a curated TPU flag
preset in the spirit of production LLM servers (saxml's
``llm_xla_flags.py``): async collectives for the sharded decode path,
memory-bound-loop and prefetch-order tuning for the KV ring traffic, and
a raised scoped-VMEM ceiling for the flash kernels.

Opt-in, mirroring ``REPRO_TUNE``: set ``REPRO_SERVE_FLAGS=1`` (or call
:func:`apply_serve_flags` before JAX initializes) and the preset is
appended to ``XLA_FLAGS``.  Flags already present in the environment win
— the preset never overrides an explicit user choice.  The preset is
TPU-only: non-TPU XLA builds abort on unknown flags, so
:func:`apply_serve_flags` no-ops unless :func:`tpu_present` says a TPU
runtime is plausibly loaded; :func:`serve_flags` still reports the
preset so tests can assert its contents anywhere.
"""

from __future__ import annotations

import glob
import importlib.util
import os

#: The serving preset.  Keys are plain XLA flag names (no ``--``); all
#: values are strings, matching how XLA parses ``XLA_FLAGS``.
SERVE_XLA_TPU_FLAGS: dict[str, str] = {
    # latency: overlap collectives with compute on the sharded decode path
    "xla_enable_async_collective_permute": "true",
    "xla_jf_spmd_threshold_for_windowed_einsum_mib": "0",
    "xla_tpu_spmd_unroll_windowed_einsum": "true",
    # bandwidth: keep the memory-bound decode loop's prefetches ordered
    "xla_tpu_enforce_prefetch_fifo_order": "true",
    "xla_tpu_memory_bound_loop_optimizer_options": "enabled:true",
    "xla_tpu_nd_short_transfer_max_chunks": "2048",
    # headroom for the split-KV flash kernels' VMEM scratch
    "xla_tpu_scoped_vmem_limit_kib": "28672",
    # inference graphs re-trace per shape: avoid layout churn
    "xla_tpu_perform_spmd_cse_prevention": "true",
    "xla_tpu_rwb_fusion": "false",
}

_ENV = "REPRO_SERVE_FLAGS"
_ON_VALUES = ("1", "on", "true")


def tpu_present() -> bool:
    """Best-effort TPU detection that is safe BEFORE ``import jax``.

    An explicit ``JAX_PLATFORMS``/``JAX_PLATFORM_NAME`` decides outright
    (a ``libtpu`` wheel is often installed on CPU-only CI images, so the
    wheel alone proves nothing).  Otherwise require both the wheel and a
    TPU device node (``/dev/accel*`` or ``/dev/vfio`` on TPU VMs)."""
    plat = os.environ.get("JAX_PLATFORMS") or os.environ.get("JAX_PLATFORM_NAME")
    if plat:
        return "tpu" in plat.lower()
    if importlib.util.find_spec("libtpu") is None:
        return False
    return bool(glob.glob("/dev/accel*")) or os.path.exists("/dev/vfio")


def serve_flags() -> dict[str, str]:
    """The preset as a dict (a copy — mutate freely)."""
    return dict(SERVE_XLA_TPU_FLAGS)


def format_flags(flags: dict[str, str]) -> str:
    """Render a flag dict in ``XLA_FLAGS`` syntax (``--k=v`` joined by
    spaces)."""
    return " ".join(f"--{k}={v}" for k, v in flags.items())


def apply_serve_flags(*, force: bool = False) -> str | None:
    """Append the serving preset to ``XLA_FLAGS`` in ``os.environ``.

    Reads ``REPRO_SERVE_FLAGS`` unless ``force=True``; flags the user
    already set in ``XLA_FLAGS`` are left alone.  Returns the new
    ``XLA_FLAGS`` value, or ``None`` when the preset is off or no TPU
    runtime is present (non-TPU XLA aborts on unknown flags).  Must run
    before the first JAX computation — XLA reads the variable once at
    backend initialization."""
    if not force and os.environ.get(_ENV, "").lower() not in _ON_VALUES:
        return None
    if not tpu_present():
        return None
    existing = os.environ.get("XLA_FLAGS", "")
    fresh = {k: v for k, v in SERVE_XLA_TPU_FLAGS.items() if f"--{k}=" not in existing}
    merged = (existing + " " + format_flags(fresh)).strip()
    os.environ["XLA_FLAGS"] = merged
    return merged
