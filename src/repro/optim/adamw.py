"""AdamW with decoupled weight decay, global-norm clipping, cosine LR.

Pure-pytree implementation (no optax dependency).  Moment tensors live in
fp32; ZeRO-1 sharding of the moments is applied by the launcher via
``sharding.partition.zero1_spec``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    """AdamW hyperparameters + the cosine LR schedule knobs."""

    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(oc: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, oc.warmup_steps)
    t = (step - oc.warmup_steps) / jnp.maximum(
        1.0, oc.total_steps - oc.warmup_steps
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init(params) -> dict:
    """Zero fp32 moment tensors (+ step counter) matching ``params``."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    """fp32 L2 norm over every leaf of ``tree`` (the clipping statistic)."""
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def update(params, grads, state, oc: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
