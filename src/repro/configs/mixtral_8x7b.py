"""Mixtral-8x7B [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window
attention (w=4096).  SWA makes it sub-quadratic: long_500k runs with the
window ring-buffer cache.  8 experts < 16-way model axis, so expert FFNs
are TP-sharded inside each expert (shard='ffn')."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    attn_kind="swa",
    window=4096,
    tie_embeddings=False,
    fsdp=True,  # 46B params
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        d_expert=14336,
        n_shared=0,
        capacity_factor=1.25,
        dispatch="dense",
        shard="ffn",
    ),
    unit=("attn_moe",),
    subquadratic=True,
    source="arXiv:2401.04088 (hf: mistralai/Mixtral-8x7B-v0.1)",
)
