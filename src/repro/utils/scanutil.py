"""Scan wrapper: lax.scan in production, bounded unroll for analysis.

XLA's ``cost_analysis()`` counts a ``while`` body exactly once, so any
scanned computation under-reports flops/bytes/collectives by its trip
count.  The dry-run's *analysis lowerings* set REPRO_UNROLL_SCANS=1 so
every library scan fully unrolls (they are all short in the reduced-unit
analysis configs) and the compiled HLO contains no loops at all.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def unroll_scans() -> bool:
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def maybe_scan(body, init, xs, *, length: int | None = None):
    """Drop-in for jax.lax.scan(body, init, xs) honoring the unroll flag."""
    if not unroll_scans():
        return jax.lax.scan(body, init, xs, length=length)
    if xs is None:
        n = length
        slices = [None] * n
    else:
        n = length or jax.tree.leaves(xs)[0].shape[0]
        slices = [jax.tree.map(lambda l: l[i], xs) for i in range(n)]
    carry = init
    ys = []
    for i in range(n):
        carry, y = body(carry, slices[i])
        ys.append(y)
    if ys and ys[0] is not None:
        ys_st = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys_st = None
    return carry, ys_st
