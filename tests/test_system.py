"""End-to-end behaviour tests: train loop improves loss, checkpoints
resume bit-exactly into the stream, and the serving engine completes
batched requests with continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticSource
from repro.models import transformer as tf
from repro.optim import adamw
from repro.serve.engine import Engine, Request
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import make_train_step


def _setup(arch="xlstm-125m-smoke", batch=4, seq=32):
    cfg = configs.get_config(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    oc = adamw.OptConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, oc, None))
    dc = DataConfig(batch=batch, seq=seq, vocab=cfg.vocab, seed=0)
    return cfg, params, opt, step, SyntheticSource(dc)


def test_training_reduces_loss_on_learnable_data():
    """Constant-token data: loss must fall fast if the whole stack
    (model, grads, optimizer) is wired correctly."""
    cfg = configs.get_config("xlstm-125m-smoke")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    oc = adamw.OptConfig(lr=5e-3, warmup_steps=2, total_steps=30, weight_decay=0.0)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, oc, None))
    toks = jnp.full((4, 32), 7, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    first = None
    for i in range(12):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.5, (first, float(m["loss"]))


def test_checkpoint_restart_is_bit_exact(tmp_path):
    cfg, params, opt, step, src = _setup()
    ck = Checkpointer(tmp_path, async_save=False)
    state = (params, opt)
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        state = step(*state, batch)[:2]
        if i == 1:
            ck.save(2, {"params": state[0], "opt": state[1]})
    final_direct = state

    skel = jax.tree.map(np.asarray, {"params": final_direct[0], "opt": final_direct[1]})
    restored = ck.restore(2, skel)
    state2 = (
        jax.tree.map(jnp.asarray, restored["params"]),
        jax.tree.map(jnp.asarray, restored["opt"]),
    )
    for i in range(2, 4):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        state2 = step(*state2, batch)[:2]
    for a, b in zip(jax.tree.leaves(final_direct[0]), jax.tree.leaves(state2[0])):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_serving_engine_continuous_batching():
    cfg = configs.get_config("xlstm-125m-smoke")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, batch_slots=2, s_max=128, prompt_bucket=32)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 20).astype(np.int32), max_new=4)
        for i in range(5)  # more requests than slots -> slot reuse
    ]
    done = engine.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) >= 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)
