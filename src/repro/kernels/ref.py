"""Pure-jnp oracles for every kernel in ``repro.kernels``.

These are the ground-truth semantics the Pallas kernels are validated
against (interpret mode on CPU, real lowering on TPU).  They are also the
*dispatch target* on non-TPU platforms: XLA fuses these into respectable
code on CPU/GPU, while the Pallas implementations own the TPU fast path.

Conventions
-----------
* numpy axis order: axis 0 slowest, axis -1 fastest (row-major), matching
  the paper's "row major linearized storage".
* The paper's ``order`` vectors (fastest-dim-first) are converted to numpy
  transpose permutations by :func:`repro.core.layout.paper_order_to_perm`.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# §III-A  basic read/write
# ---------------------------------------------------------------------------


def copy(x: Array) -> Array:
    """Contiguous device-to-device copy (the paper's read/write kernel)."""
    return x + jnp.zeros((), x.dtype)  # force a materialized copy under jit


def copy_range(x: Array, start: int, size: int) -> Array:
    """Ranged access: copy ``x[start:start+size]`` along axis 0."""
    return jax.lax.dynamic_slice_in_dim(x, start, size, axis=0)


def gather_rows(x: Array, idx: Array) -> Array:
    """Index-set access: rows of ``x`` (axis 0) selected by ``idx``."""
    return jnp.take(x, idx, axis=0)


def gather_rows_masked(x: Array, idx: Array) -> Array:
    """Sentinel-aware index-set access: ``out[i] = x[idx[i]]`` with
    ``idx[i] < 0`` producing a zero row (the in-kernel masking semantics
    of the blocked gather, DESIGN.md §4)."""
    if x.shape[0] == 0:
        return jnp.zeros((idx.shape[0],) + x.shape[1:], x.dtype)
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    rows = jnp.take(x, safe, axis=0)
    mask = (idx >= 0).reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, rows, jnp.zeros((), x.dtype))


def scatter_rows(x: Array, idx: Array, num_out: int | None = None) -> Array:
    """Permutation scatter: ``out[idx[i]] = x[i]``.  ``idx`` must be a
    permutation (or injective into ``num_out`` rows)."""
    n = x.shape[0] if num_out is None else num_out
    out = jnp.zeros((n,) + x.shape[1:], x.dtype)
    return out.at[idx].set(x, mode="drop")


def gather_combine(src: Array, back: Array, gates: Array) -> Array:
    """Fused gather + weighted combine oracle:
    ``out[t] = sum_k gates[t, k] * src[back[t, k]]``; ``back[t, k] < 0``
    contributes zero.  Ground truth for
    `gather_scatter.gather_combine_blocked` (products and the k-sum run in
    ``src.dtype``, matching the unfused gather->multiply->sum chain)."""
    t, k = back.shape
    rows = gather_rows_masked(src, back.reshape(-1)).reshape(t, k, src.shape[1])
    return (rows * gates.astype(rows.dtype)[..., None]).sum(axis=1)


# ---------------------------------------------------------------------------
# §III-B  permute / reorder
# ---------------------------------------------------------------------------


def transpose2d(x: Array) -> Array:
    """2-D transpose — the building block of every reorder."""
    return x.T


def transpose2d_batched(x: Array) -> Array:
    """(B, R, C) -> (B, C, R): batched 2-D transpose."""
    return jnp.swapaxes(x, -1, -2)


def permute(x: Array, perm: Sequence[int]) -> Array:
    """N-D permute with a numpy-convention permutation."""
    return jnp.transpose(x, tuple(perm))


def reorder_nm(
    x: Array,
    perm: Sequence[int],
    base: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
) -> Array:
    """The paper's generic N->M reorder: slice a window ``[base, base+sizes)``
    out of ``x``, transpose the kept axes into ``perm`` order, and squeeze
    axes not present in ``perm`` (their window size must be 1).

    ``perm`` lists the *input* axes (numpy convention) that appear in the
    output, slowest-first.  Axes of ``x`` not in ``perm`` are reduced to a
    single element selected by ``base``.
    """
    nd = x.ndim
    base = [0] * nd if base is None else list(base)
    sizes = list(x.shape) if sizes is None else list(sizes)
    kept = set(int(p) for p in perm)
    for ax in range(nd):
        if ax not in kept and sizes[ax] != 1:
            raise ValueError(
                f"axis {ax} dropped by perm {perm} must have window size 1, "
                f"got {sizes[ax]}"
            )
    window = jax.lax.dynamic_slice(x, base, sizes)
    full_perm = list(perm) + [ax for ax in range(nd) if ax not in kept]
    moved = jnp.transpose(window, full_perm)
    return moved.reshape(tuple(sizes[ax] for ax in perm))


def bit_reversal(x: Array, *, axis: int = 0) -> Array:
    """Bit-reversal reorder along ``axis`` (FFT layouts): element ``i`` moves
    to the index whose base-2 digits are ``i``'s reversed.  The axis length
    must be a power of two."""
    n = x.shape[axis]
    if n & (n - 1):
        raise ValueError(f"bit_reversal axis length {n} is not a power of 2")
    bits = max(n.bit_length() - 1, 0)
    i = jnp.arange(n)
    rev = jnp.zeros_like(i)
    for b in range(bits):
        rev = rev | (((i >> b) & 1) << (bits - 1 - b))
    return jnp.take(x, rev, axis=axis)


def strided_gather(x: Array, stride: int, *, phase: int = 0, axis: int = 0) -> Array:
    """Strided slice ``x[..., phase::stride, ...]`` along ``axis`` (the
    affine window/stride class)."""
    if stride <= 0:
        raise ValueError(f"stride must be positive, got {stride}")
    idx = jnp.arange(phase, x.shape[axis], stride)
    return jnp.take(x, idx, axis=axis)


def diagonal_reorder(x: Array) -> Array:
    """Skewed-diagonal reorder of the trailing plane:
    ``out[..., i, j] = x[..., i, (i + j) % C]`` (the paper's diagonal block
    walk applied to the data itself — cyclically shift row ``i`` left by
    ``i``)."""
    if x.ndim < 2:
        raise ValueError("diagonal_reorder wants rank >= 2")
    rows, cols = x.shape[-2], x.shape[-1]
    i = jnp.arange(rows)[:, None]
    j = jnp.arange(cols)[None, :]
    idx = jnp.broadcast_to((i + j) % max(cols, 1), x.shape[-2:])
    return jnp.take_along_axis(x, jnp.broadcast_to(idx, x.shape), axis=-1)


def shuffle(x: Array, seed: int = 0) -> Array:
    """Seeded bijective row shuffle along axis 0: the same mixed-radix
    digit-permute + per-digit-rotation bijection the table-free Pallas
    route lowers (``affine.shuffle_map``), materialized here as one gather
    through the map's index table."""
    from repro.core import affine  # lazy: keep ref importable standalone

    n = x.shape[0]
    if n <= 1:
        return x + jnp.zeros((), x.dtype)
    amap = affine.shuffle_map(n, seed=seed)
    return jnp.take(x, jnp.asarray(amap.index_vector()), axis=0)


# ---------------------------------------------------------------------------
# §III-C  interlace / de-interlace
# ---------------------------------------------------------------------------


def interlace(arrays: Sequence[Array]) -> Array:
    """n arrays of shape (..., L) -> one array (..., L*n) with
    ``out[..., j*n + k] = arrays[k][..., j]`` (AoS from SoA)."""
    stacked = jnp.stack(arrays, axis=-1)  # (..., L, n)
    return stacked.reshape(*stacked.shape[:-2], -1)


def deinterlace(x: Array, n: int) -> list[Array]:
    """Inverse of :func:`interlace`: (..., L*n) -> n arrays (..., L)."""
    if x.shape[-1] % n:
        raise ValueError(f"last dim {x.shape[-1]} not divisible by n={n}")
    split = x.reshape(*x.shape[:-1], x.shape[-1] // n, n)
    return [split[..., k] for k in range(n)]


# ---------------------------------------------------------------------------
# §III-D  generic 2-D stencil
# ---------------------------------------------------------------------------

# boundary-condition family (DESIGN.md §9): name -> jnp.pad mode.  'clamp'
# is a back-compat alias for 'nearest'.
BOUNDARY_PAD_MODES = {
    "zero": "constant",
    "nearest": "edge",
    "clamp": "edge",
    "reflect": "reflect",
    "periodic": "wrap",
}


def pad_boundary(x: Array, radius: int, boundary: str) -> Array:
    """Extend ``x`` by ``radius`` cells on every side per the boundary
    condition: ``zero`` (constant 0), ``nearest`` (edge replicate),
    ``reflect`` (mirror about the edge cell), ``periodic`` (wrap)."""
    if boundary not in BOUNDARY_PAD_MODES:
        raise ValueError(
            f"unknown boundary {boundary!r}; want one of {sorted(BOUNDARY_PAD_MODES)}"
        )
    return jnp.pad(x, radius, mode=BOUNDARY_PAD_MODES[boundary])


def stencil2d(
    x: Array,
    offsets: Sequence[tuple[int, int]],
    weights: Array,
    *,
    boundary: str = "zero",
) -> Array:
    """Weighted-sum stencil: ``out[y,x] = sum_k w[k] * in[y+dy_k, x+dx_k]``.

    boundary: one of ``zero | nearest | reflect | periodic`` (see
    :func:`pad_boundary`; 'clamp' is accepted as an alias for 'nearest').
    """
    r = max(max(abs(dy), abs(dx)) for dy, dx in offsets)
    xp = pad_boundary(x, r, boundary)
    h, w = x.shape
    out = jnp.zeros_like(x)
    for (dy, dx), wk in zip(offsets, weights):
        out = out + wk * jax.lax.dynamic_slice(xp, (r + dy, r + dx), (h, w))
    return out


def stencil2d_functor(
    x: Array,
    functor: Callable[..., Array],
    radius: int,
    *,
    boundary: str = "zero",
    aux: Array | None = None,
) -> Array:
    """Generic functor stencil (the paper's template/functor mechanism).

    ``functor(shift)`` receives a function ``shift(dy, dx) -> Array`` that
    returns the input shifted by (dy, dx) (same shape as ``x``), and returns
    the output grid.  Arbitrary point-wise combinations are allowed, e.g.::

        def laplace(shift):
            return shift(-1, 0) + shift(1, 0) + shift(0, -1) + shift(0, 1) \
                   - 4.0 * shift(0, 0)

    With ``aux`` (an extra same-shape array, e.g. a Poisson source term) the
    functor is called as ``functor(shift, src)`` where ``src()`` returns the
    aux grid.
    """
    xp = pad_boundary(x, radius, boundary)
    h, w = x.shape

    def shift(dy: int, dx: int) -> Array:
        if max(abs(dy), abs(dx)) > radius:
            raise ValueError(f"shift ({dy},{dx}) exceeds radius {radius}")
        return jax.lax.dynamic_slice(xp, (radius + dy, radius + dx), (h, w))

    if aux is None:
        return functor(shift)
    return functor(shift, lambda: aux)


def stencil_pipeline(
    x: Array,
    stages: Sequence[tuple[Callable[..., Array], int]],
    *,
    boundary: str = "zero",
    aux: Array | None = None,
) -> Array:
    """Oracle for a multi-stage stencil program: apply each ``(functor,
    radius)`` stage as one full-grid sweep, re-extending the boundary
    between sweeps.  This is the k-HBM-round-trip semantics the fused
    temporal-blocking kernel (``stencil2d.stencil2d_pipeline``) must match.
    """
    for functor, radius in stages:
        x = stencil2d_functor(x, functor, radius, boundary=boundary, aux=aux)
    return x


def stencil_pipeline_window(
    x: Array,
    stages: Sequence[tuple[Callable[..., Array], int]],
    *,
    boundary: str = "zero",
    row0: Array | int = 0,
    global_rows: int | None = None,
) -> Array:
    """Oracle for a stencil program on a *window* of a larger global grid
    (the §10 halo-exchange semantics, mirrored from the fused kernel's
    ``row0``/``global_rows`` mode).

    ``x`` holds rows ``[row0, row0 + x.shape[0])`` of a ``global_rows``-row
    grid (``row0`` may be traced — it is ``axis_index * rows_per_shard``
    under `shard_map`); columns are complete.  Each stage re-extends the
    row boundary *in global coordinates*: rows outside the global domain
    are rebuilt from in-domain rows per the boundary mode (periodic rows
    are already resident — the ring exchange delivered them), then the
    stage sweeps with the true column boundary.  Rows whose dependency cone
    leaves the window come out contaminated and must be cropped by the
    caller (``sum(radius_i)`` rows per side — `core/dist_plan.py` does).
    """
    if boundary not in BOUNDARY_PAD_MODES:
        raise ValueError(
            f"unknown boundary {boundary!r}; want one of {sorted(BOUNDARY_PAD_MODES)}"
        )
    h_ext, w = x.shape
    hg = h_ext if global_rows is None else int(global_rows)
    g = jnp.asarray(row0, jnp.int32) + jnp.arange(h_ext, dtype=jnp.int32)
    for functor, r in stages:
        if boundary == "periodic" or hg <= 0:
            cur = x
        elif boundary == "zero":
            inside = (g >= 0) & (g < hg)
            cur = jnp.where(inside[:, None], x, jnp.zeros((), x.dtype))
        else:
            if boundary == "reflect" and hg > 1:
                p = 2 * hg - 2
                m = g % p
                src = jnp.where(m < hg, m, p - m)
            else:  # nearest / clamp (and reflect on a 1-row grid)
                src = jnp.clip(g, 0, hg - 1)
            pos = jnp.clip(src - jnp.asarray(row0, jnp.int32), 0, h_ext - 1)
            cur = jnp.take(x, pos, axis=0)
        # rows beyond the window only feed contaminated (cropped) outputs:
        # a zero row pad is enough.  Columns are complete, so the column
        # boundary is the true one.
        xp = jnp.pad(cur, ((r, r), (0, 0)))
        if r:
            xp = jnp.pad(xp, ((0, 0), (r, r)), mode=BOUNDARY_PAD_MODES[boundary])

        def shift(dy: int, dx: int, _xp=xp, _r=r) -> Array:
            if max(abs(dy), abs(dx)) > _r:
                raise ValueError(f"shift ({dy},{dx}) exceeds radius {_r}")
            return jax.lax.dynamic_slice(_xp, (_r + dy, _r + dx), (h_ext, w))

        x = functor(shift)
    return x


def fd_stencil_offsets(order: int) -> tuple[list[tuple[int, int]], list[float]]:
    """Central finite-difference Laplacian stencil of a given order
    (paper Fig. 2 runs orders I..IV — half-widths 1..4 along each axis).

    Returns cross-shaped (offsets, weights) for the 2-D Laplacian using
    standard central-difference coefficients of accuracy 2*order.
    """
    coeffs = {
        1: [-2.0, 1.0],
        2: [-5.0 / 2.0, 4.0 / 3.0, -1.0 / 12.0],
        3: [-49.0 / 18.0, 3.0 / 2.0, -3.0 / 20.0, 1.0 / 90.0],
        4: [-205.0 / 72.0, 8.0 / 5.0, -1.0 / 5.0, 8.0 / 315.0, -1.0 / 560.0],
    }[order]
    offsets: list[tuple[int, int]] = [(0, 0)]
    weights: list[float] = [2.0 * coeffs[0]]  # d2/dy2 + d2/dx2 share center
    for k in range(1, order + 1):
        for off in ((k, 0), (-k, 0), (0, k), (0, -k)):
            offsets.append(off)
            weights.append(coeffs[k])
    return offsets, weights


# ---------------------------------------------------------------------------
# attention oracle (flash forward/backward ground truth, DESIGN.md §13)
# ---------------------------------------------------------------------------


def attention(
    q: Array,  # (B, Hq, Sq, D)
    k: Array,  # (B, Hkv, Skv, D)
    v: Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
) -> Array:
    """Naive GQA attention: materializes the full (Sq, Skv) matrix in fp32.

    Exact semantics of ``kernels.flash.flash_attention`` — unscaled
    ``softmax(q k^T) v`` (callers pre-scale q by 1/sqrt(d)), causal mask
    at absolute query position ``q_offset + i``, kv head ``h // g`` serving
    query head ``h``.  Ground truth for the gradient-correctness tier
    (tests/test_train_engine.py) and the second-order fallback of the flash
    backward custom VJP; it is fully differentiable to any order.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=1) if g > 1 else k
    vv = jnp.repeat(v, g, axis=1) if g > 1 else v
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    )
    if causal:
        q_pos = q_offset + jnp.arange(sq)[:, None]
        k_pos = jnp.arange(skv)[None, :]
        s = jnp.where(q_pos >= k_pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)
