"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON records.

  PYTHONPATH=src python -m repro.utils.report [--dir runs/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(d: Path) -> list[dict]:
    return [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))]


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(records: list[dict]) -> str:
    lines = [
        "| arch | cell | status | compile s | peak GB/dev | temp GB/dev | collectives (scan-mode HLO) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['cell']} | skipped | - | - | - | {r.get('reason','')[:70]} |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['cell']} | ERROR | - | - | - | {r.get('error','')[:70]} |"
            )
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives_scan_mode", {}).get("counts", {})
        coll_s = " ".join(f"{k.split('-')[1] if '-' in k else k}:{v}" for k, v in sorted(coll.items()))
        peak = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        lines.append(
            f"| {r['arch']} | {r['cell']} | ok | {r.get('compile_s','-')} "
            f"| {fmt_bytes(peak)} | {fmt_bytes(mem.get('temp_bytes'))} | {coll_s} |"
        )
    return "\n".join(lines)


def roofline_table(records: list[dict]) -> str:
    lines = [
        "| arch | cell | compute s | memory s | collective s | bottleneck | step s | useful_ratio | mfu_bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if "roofline" not in r:
            if r.get("status") == "skipped":
                lines.append(f"| {r['arch']} | {r['cell']} | - | - | - | skipped | - | - | - |")
            continue
        x = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['cell']} | {x['compute_s']:.3f} | {x['memory_s']:.3f} "
            f"| {x['collective_s']:.3f} | **{x['bottleneck']}** | {x['step_time_s']:.3f} "
            f"| {x['useful_flops_ratio']:.2f} | {x['mfu_bound']:.3f} |"
        )
    return "\n".join(lines)


def pick_hillclimb(records: list[dict]) -> list[str]:
    """worst mfu_bound, most collective-bound, most paper-representative."""
    ok = [r for r in records if "roofline" in r]
    if not ok:
        return []
    worst = min(ok, key=lambda r: r["roofline"]["mfu_bound"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"] / max(r["roofline"]["step_time_s"], 1e-9))
    moe = [r for r in ok if "moe" in r["arch"]]
    rep = max(moe, key=lambda r: r["roofline"]["step_time_s"]) if moe else ok[0]
    out = []
    for tag, r in [("worst-mfu", worst), ("most-collective", coll), ("paper-representative(MoE dispatch)", rep)]:
        out.append(f"{tag}: {r['arch']} x {r['cell']} (bottleneck={r['roofline']['bottleneck']})")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    args = ap.parse_args()
    single = load(Path(args.dir) / "single")
    print("## Dry-run (single-pod 16x16)\n")
    print(dryrun_table(single))
    multi_dir = Path(args.dir) / "multi"
    if multi_dir.exists():
        print("\n## Dry-run (multi-pod 2x16x16)\n")
        print(dryrun_table(load(multi_dir)))
    print("\n## Roofline (single-pod, per-device terms)\n")
    print(roofline_table(single))
    print("\n## Suggested hillclimb pairs\n")
    for line in pick_hillclimb(single):
        print("-", line)


if __name__ == "__main__":
    main()
