"""Model/arch configuration schema.

Every assigned architecture is a ``ModelConfig``; the layer stack is
described by a *plan* — a sequence of (unit, count) pairs where a unit is
a tuple of block kinds executed in order and scanned ``count`` times.
Kinds:

  attn        self-attention (cfg.attn_kind: full|swa) + dense MLP
  attn_dense  self-attention + dense MLP with ``d_ff_dense`` (deepseek L0)
  attn_moe    self-attention + MoE FFN
  local       local (windowed) self-attention + dense MLP
  xattn       cross-attention (image/frames source) + dense MLP
  dec         decoder block: self-attn + cross-attn(encoder) + MLP
  enc         bidirectional self-attention + MLP (encoder stack)
  mlstm       xLSTM matrix-memory block (self-contained)
  slstm       xLSTM scalar-memory block (self-contained)
  rglru       RG-LRU recurrent block + dense MLP
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    normalize_gates: bool = True
    dispatch: str = "dense"  # dense (GSPMD all-to-all) | sort (gather kernels)
    shard: str = "expert"  # expert (EP on model axis) | ffn (TP inside experts)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    act: str = "swiglu"  # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"
    pos_embed: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 1_000_000.0
    attn_kind: str = "full"  # full | swa
    attn_shard: str = "none"  # none | head | seq — set by the launcher
    sp: bool = False  # sequence-parallel residual stream — set by the launcher
    window: int = 4096
    moe: MoEConfig | None = None
    unit: tuple[str, ...] = ("attn",)
    explicit_plan: tuple[tuple[tuple[str, ...], int], ...] | None = None
    encoder_layers: int = 0
    n_frontend_tokens: int = 0  # stub modality frontend (audio frames / image patches)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    fsdp: bool = False
    remat: bool = True
    remat_policy: str = "nothing_saveable"  # see models.common.REMAT_POLICIES
    blockwise: bool = False  # blockwise-parallel training blocks (DESIGN §13)
    blockwise_chunk: int = 1024  # query/sequence chunk for blockwise attn+FFN
    loss_chunk: int = 2048
    attn_chunk: int = 512
    d_ff_dense: int | None = None
    subquadratic: bool = False  # may run long_500k
    source: str = ""  # provenance note

    # ---- derived ----
    @property
    def use_rope(self) -> bool:
        return self.pos_embed == "rope"

    @property
    def head_dim_resolved(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_plan(self) -> tuple[tuple[tuple[str, ...], int], ...]:
        """[(unit, count), ...] covering exactly n_layers block entries."""
        if self.explicit_plan is not None:
            plan = self.explicit_plan
        else:
            u = len(self.unit)
            count, rem = divmod(self.n_layers, u)
            plan = (((self.unit), count),)
            if rem:
                plan = plan + ((self.unit[:rem], 1),)
        total = sum(len(unit) * cnt for unit, cnt in plan)
        if total != self.n_layers:
            raise ValueError(
                f"{self.name}: plan covers {total} layers, expected {self.n_layers}"
            )
        return plan

    def decoder_plan(self):
        return self.layer_plan()

    def encoder_plan(self):
        if not self.encoder_layers:
            return ()
        return ((("enc",), self.encoder_layers),)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(
            len(cfg.unit) if cfg.explicit_plan is None else 2, len(cfg.unit)
        ),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=256,
        head_dim=16,
        window=16,
        loss_chunk=32,
        attn_chunk=32,
        encoder_layers=min(cfg.encoder_layers, 2),
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8) if cfg.n_frontend_tokens else 0,
        fsdp=False,
        name=cfg.name + "-smoke",
    )
    if cfg.explicit_plan is not None:
        # shrink counts to 1 per unit kind
        kw["explicit_plan"] = tuple((unit, 1) for unit, _ in cfg.explicit_plan)
        kw["n_layers"] = sum(len(u) for u, _ in kw["explicit_plan"])
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            d_expert=64,
            n_shared=min(cfg.moe.n_shared, 1),
            capacity_factor=8.0,  # dropless at smoke scale: decode tests exact
            dispatch=cfg.moe.dispatch,
        )
        kw["d_ff_dense"] = 128 if cfg.d_ff_dense else None
    return cfg.with_(**kw)
