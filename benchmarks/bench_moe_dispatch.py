"""Beyond-paper: MoE dispatch as the index-set rearrangement (DESIGN §4).

Three dispatch strategies at equal semantics, benchmarked head-to-head:

* ``dense``       — one-hot einsum dispatch/combine (the distributed path);
* ``sort_rowwise``— the seed kernel path: per-row gathers around two
                    sentinel-row concatenates and an unfused combine;
* ``sort_fused``  — the IndexPlan engine path: ONE blocked masked gather
                    + ONE fused gather+weighted-combine (2 pallas_calls).

Off-TPU the two sort paths run through the Pallas interpreter (like
bench_permute's head family) so the kernels themselves are measured; the
dense row keeps the default dispatch.  Byte accounting uses the actual
activation ``dtype.itemsize`` — the seed hardcoded 4 B/element while
``cfg.np_dtype`` is bf16, overstating GB/s 2x — and includes the int32
index-table traffic, both taken from the IndexPlan cost model so achieved
and predicted movement share one definition.  Rows land in
``BENCH_moe.json`` (see benchmarks/run.py) with the plan-mode fields.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import row, smoke, time_fn
from repro import configs
from repro.core.index_plan import plan_index_op
from repro.models import moe

# sized for interpret-mode kernel measurement off-TPU: the interpreter
# expands every grid step at trace time, so the per-row baseline's
# (E*cap)-step grid bounds what is traceable in reasonable time
B, S = 2, 64


def _sort_traffic_bytes(cfg, t: int, cap: int) -> tuple[int, dict]:
    """Dispatch+combine HBM traffic of the sort path (both engines move the
    same algorithmic bytes), from the IndexPlan cost model."""
    e, k, d = cfg.moe.n_experts, cfg.moe.top_k, cfg.d_model
    dt = cfg.np_dtype
    disp = plan_index_op((t, d), dt, e * cap, "gather", masked=True)
    comb = plan_index_op((e * cap, d), dt, t, "gather_combine", masked=True, top_k=k)
    meta = {
        "dispatch_plan": disp.describe(),
        "combine_plan": comb.describe(),
        "plan_bytes_dispatch": disp.bytes_moved,
        "plan_bytes_combine": comb.bytes_moved,
    }
    return disp.bytes_moved + comb.bytes_moved, meta


def run() -> list[str]:
    b, s = (2, 16) if smoke() else (B, S)
    cfg = configs.get_config("deepseek-moe-16b-smoke").with_(
        d_model=128 if smoke() else 256
    )
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(key, cfg)
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32).astype(cfg.np_dtype)
    t = b * s
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    cap = moe.default_capacity(cfg, t)
    nbytes, meta = _sort_traffic_bytes(cfg, t, cap)

    out = [f"# tokens={t} d={cfg.d_model} dtype={jnp.dtype(cfg.np_dtype).name} "
           f"E={e} k={k} cap={cap}"]

    # dense: the one-hot einsum formulation (XLA path, default dispatch)
    cfg_d = cfg.with_(moe=cfg.moe.__class__(**{**cfg.moe.__dict__, "dispatch": "dense"}))
    fn = jax.jit(lambda a, c=cfg_d: moe.moe_apply(p, c, a)[0])
    t_dense = time_fn(fn, x)
    out.append(
        row("moe_dispatch_dense", t_dense, nbytes,
            plan_mode="dense_einsum", measured="xla_oracle", tokens=t, cap=cap)
    )

    # the two sort engines, kernels measured via the interpreter off-TPU
    force_interp = jax.default_backend() != "tpu"
    prev = os.environ.get("REPRO_PALLAS_INTERPRET")
    if force_interp:
        os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    try:
        fn_row = jax.jit(
            lambda a: moe.moe_sort(p, cfg, a, capacity=cap, engine="rowwise")[0]
        )
        t_row = time_fn(fn_row, x)
        out.append(
            row("moe_dispatch_sort_rowwise", t_row, nbytes,
                "[seed per-row kernels]",
                plan_mode="rowwise", measured="pallas", tokens=t, cap=cap)
        )
        fn_fused = jax.jit(
            lambda a: moe.moe_sort(p, cfg, a, capacity=cap, engine="plan")[0]
        )
        t_fused = time_fn(fn_fused, x)
        out.append(
            row("moe_dispatch_sort_fused", t_fused, nbytes,
                f"[IndexPlan engine, {t_row/t_fused:.2f}x vs rowwise]",
                plan_mode="blocked", measured="pallas", tokens=t, cap=cap,
                improvement_vs_rowwise=round(t_row / t_fused, 3), **meta)
        )
        # equivalence records (recorded, not asserted: the tier-1
        # equivalence tests own the hard checks): the fused engine must be
        # bit-identical to the seed rowwise engine, and agree with the
        # dense one-hot oracle at equal (dropless) capacity up to its
        # different einsum summation order
        y_fused = fn_fused(x)
        same = bool(jnp.all(fn_row(x) == y_fused))
        cap_dropless = t * k
        y_dense = jax.jit(
            lambda a: moe.moe_apply(p, cfg_d, a, capacity=cap_dropless)[0]
        )(x)
        y_sort_dl = jax.jit(
            lambda a: moe.moe_sort(p, cfg, a, capacity=cap_dropless, engine="plan")[0]
        )(x)
        dense_dev = float(
            jnp.max(jnp.abs(y_dense.astype(jnp.float32) - y_sort_dl.astype(jnp.float32)))
        )
        out.append(
            f"# fused vs rowwise bit-identical: {same}; "
            f"max |fused - dense| at dropless capacity: {dense_dev:.2e}"
        )
        from benchmarks import common

        if common.RECORDS:
            common.RECORDS[-1]["bit_identical_vs_rowwise"] = same
            common.RECORDS[-1]["max_abs_dev_vs_dense_dropless"] = dense_dev
    finally:
        if force_interp:
            if prev is None:
                os.environ.pop("REPRO_PALLAS_INTERPRET", None)
            else:
                os.environ["REPRO_PALLAS_INTERPRET"] = prev
    return out
