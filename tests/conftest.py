import os

# Kernel tests exercise the Pallas implementations in interpret mode.
# This is per-test opt-in via the `pallas_interpret` fixture — NOT global —
# so model smoke tests see the default dispatch (jnp oracle on CPU).
import pytest


@pytest.fixture
def pallas_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    yield
