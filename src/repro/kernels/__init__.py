"""Pallas TPU kernels for the paper's data rearrangement library.

Layout:
  <name>.py        pl.pallas_call + BlockSpec VMEM tiling per kernel family
  ops.py           jit'd dispatch wrappers (Pallas on TPU, oracle elsewhere)
  ref.py           pure-jnp oracles (ground truth + CPU dispatch target)
"""
