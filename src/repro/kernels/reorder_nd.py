"""Generic N-D reorder kernel (paper §III-B "Reorder Kernel"), TPU-native.

The paper's canonicalization — *every valid reorder reduces to batched 2-D
data movement in the plane of the fastest-changing input dim and the
fastest-changing output dim* — is kept intact.  What changes on TPU:

* CUDA stores the stride tables in **constant memory**; every thread reads
  them to compute its source address.  On TPU we go one better: block
  indices are computed *arithmetically in the scalar core* inside the
  BlockSpec ``index_map`` (mixed-radix decomposition of the linearized
  batch grid index, with radices baked in as compile-time constants).
  Zero memory traffic for metadata, and no 5-dim performance cliff — the
  paper's Table 2 shows 43 GB/s at 5-D because of metadata-lookup overhead;
  our index arithmetic is free relative to the DMAs it schedules.
* Exactly **two axes are blocked**: the input-fastest axis (lane dim of the
  load tile) and the axis that becomes output-fastest (lane dim of the
  store tile).  All other axes are batch.  Both DMAs therefore move full
  lane-aligned tiles — coalesced-on-both-sides, per the paper.
* If the permutation *preserves* the fastest axis ("copy mode"), the kernel
  degenerates to a blocked gather of contiguous rows — the paper's N-to-M
  case with preserved dim-0.

``perm`` uses numpy convention: ``out axis j  <-  in axis perm[j]``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (
    cdiv,
    force_interpret,
    plan_copy_tiles,
    plan_transpose_tiles,
)


def _permute_kernel(perm, x_ref, o_ref):
    o_ref[...] = jnp.transpose(x_ref[...], perm)


def _dim_semantics(n: int):
    try:
        return pltpu.CompilerParams(dimension_semantics=(pltpu.ARBITRARY,) * n)
    except Exception:  # pragma: no cover
        return None


@functools.partial(
    jax.jit,
    static_argnames=("perm", "block_r", "block_c", "grid_order", "interpret"),
)
def permute_nd(
    x: jax.Array,
    perm: tuple[int, ...],
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    grid_order: str = "out",
    interpret: bool | None = None,
) -> jax.Array:
    """General N-D permute: ``out = jnp.transpose(x, perm)`` as a tiled
    Pallas data-movement kernel.

    grid_order: 'out' walks batch blocks in output-linear order (stores are
    sequential in HBM), 'in' walks in input-linear order (loads sequential).
    This is the TPU analogue of the paper's block-scheduling policies.
    """
    N = x.ndim
    perm = tuple(int(p) for p in perm)
    if sorted(perm) != list(range(N)):
        raise ValueError(f"bad perm {perm} for rank {N}")
    out_shape = tuple(x.shape[p] for p in perm)
    if N == 0 or perm == tuple(range(N)):
        # identity: fall through to a plain copy (still a kernel-shaped op)
        return x + jnp.zeros((), x.dtype)

    c_in = N - 1  # input-fastest axis
    transpose_mode = perm[-1] != c_in
    if transpose_mode:
        r_in = perm[-1]  # axis that becomes output-fastest
    else:
        # fastest axis preserved: block the axis that becomes 2nd-fastest out
        r_in = perm[-2] if N >= 2 else c_in

    R, C = x.shape[r_in], x.shape[c_in]
    if transpose_mode:
        plan = plan_transpose_tiles(R, C, x.dtype)
    else:
        plan = plan_copy_tiles(R, C, x.dtype)
    br = min(block_r or plan.block_r, R)
    bc = min(block_c or plan.block_c, C)

    # per-axis block size and block count
    blocks = [1] * N
    blocks[r_in], blocks[c_in] = br, bc
    nblocks = [cdiv(x.shape[k], blocks[k]) for k in range(N)]

    # batch axes (all but r_in/c_in), walked in in- or out-linear order
    if grid_order == "out":
        batch_in_axes = [p for p in perm if p not in (r_in, c_in)]
    elif grid_order == "in":
        batch_in_axes = [k for k in range(N) if k not in (r_in, c_in)]
    else:
        raise ValueError(f"grid_order must be 'in' or 'out', got {grid_order!r}")
    batch_radix = [nblocks[a] for a in batch_in_axes]
    G = math.prod(batch_radix) if batch_radix else 1

    # mixed-radix weights: coordinate of batch axis a = (g // w[a]) % radix[a]
    weights: dict[int, int] = {}
    w = 1
    for a, r in zip(reversed(batch_in_axes), reversed(batch_radix)):
        weights[a] = w
        w *= r

    def in_coords(g, i, j):
        coords = []
        for k in range(N):
            if k == r_in:
                coords.append(i)
            elif k == c_in:
                coords.append(j)
            else:
                coords.append(lax.rem(g // weights[k], nblocks[k]))
        return coords

    def in_map(g, i, j):
        return tuple(in_coords(g, i, j))

    def out_map(g, i, j):
        c = in_coords(g, i, j)
        return tuple(c[p] for p in perm)

    in_block = tuple(blocks)
    out_block = tuple(blocks[p] for p in perm)

    interpret = force_interpret() if interpret is None else interpret
    params = _dim_semantics(3)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        functools.partial(_permute_kernel, perm),
        grid=(G, nblocks[r_in], nblocks[c_in]),
        in_specs=[pl.BlockSpec(in_block, in_map)],
        out_specs=pl.BlockSpec(out_block, out_map),
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        interpret=interpret,
        **kwargs,
    )(x)
