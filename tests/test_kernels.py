"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import copy as copy_k
from repro.kernels import gather_scatter as gs_k
from repro.kernels import interlace as il_k
from repro.kernels import permute3d as p3_k
from repro.kernels import ref
from repro.kernels import reorder_nd as rnd_k
from repro.kernels import stencil2d as st_k

RNG = np.random.default_rng(42)

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.int8]


def rand(shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(RNG.integers(-100, 100, shape), dtype)
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# §III-A copy / ranged / index-set
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(8, 128), (64, 256), (33, 130), (3, 17, 256)])
def test_copy(shape, dtype):
    x = rand(shape, dtype)
    out = copy_k.copy(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@pytest.mark.parametrize("start,size", [(0, 8), (7, 20), (40, 24)])
def test_copy_range(start, size):
    x = rand((64, 256), jnp.float32)
    out = copy_k.copy_range(x, jnp.int32(start), size, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x)[start : start + size])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,c", [(16, 128), (37, 200), (64, 384)])
def test_gather_scatter_rows(n, c, dtype):
    x = rand((n, c), dtype)
    idx = jnp.asarray(RNG.permutation(n), jnp.int32)
    g = gs_k.gather_rows(x, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(x)[np.asarray(idx)])
    s = gs_k.scatter_rows(x, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(s)[np.asarray(idx)], np.asarray(x))


def test_gather_rows_with_duplicates():
    x = rand((16, 128), jnp.float32)
    idx = jnp.asarray([0, 0, 3, 15, 3, 1, 1, 1], jnp.int32)
    g = gs_k.gather_rows(x, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(x)[np.asarray(idx)])


def _masked_take(x, idx):
    safe = np.clip(idx, 0, x.shape[0] - 1)
    return np.where((idx >= 0)[:, None], np.asarray(x)[safe], 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block_r", [1, 4, 8, 64])
def test_gather_rows_blocked_vs_oracle(block_r, dtype):
    """Blocked masked gather across block sizes: sentinels, duplicates,
    and a contiguous run that exercises the run-detection fast path."""
    x = rand((40, 130), dtype)
    idx = jnp.asarray(
        list(range(8, 24)) + [-1, 0, 0, 39, -7, 5] + list(range(10)), jnp.int32
    )
    got = gs_k.gather_rows_blocked(x, idx, block_r=block_r, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), _masked_take(x, np.asarray(idx))
    )


def test_gather_rows_blocked_pure_run_fast_path():
    """A fully contiguous table must hit the single-block-copy path and
    stay exact (same result as the row-by-row path)."""
    x = rand((64, 128), jnp.float32)
    idx = jnp.arange(64, dtype=jnp.int32)
    got = gs_k.gather_rows_blocked(x, idx, block_r=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    # misaligned run start
    idx2 = jnp.arange(5, 37, dtype=jnp.int32)
    got2 = gs_k.gather_rows_blocked(x, idx2, block_r=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(x)[5:37])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,k,block_t", [(23, 3, 8), (16, 2, 16), (7, 1, 4)])
def test_gather_combine_blocked_vs_oracle(t, k, block_t, dtype):
    src = rand((37, 130), dtype)
    back = jnp.asarray(RNG.integers(-1, 37, (t, k)), jnp.int32)
    gates = jnp.asarray(RNG.standard_normal((t, k)), jnp.float32)
    got = gs_k.gather_combine_blocked(
        src, back, gates, block_t=block_t, interpret=True
    )
    want = jax.jit(ref.gather_combine)(src, back, gates)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6, atol=1e-2,
    )


# ---------------------------------------------------------------------------
# §III-B permute / reorder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,r,c", [(3, 40, 257), (1, 128, 128), (5, 7, 9)])
def test_transpose2d_batched(b, r, c, dtype):
    x = rand((b, r, c), dtype)
    out = p3_k.transpose2d_batched(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.swapaxes(np.asarray(x), 1, 2))


def test_transpose_diagonal_walk():
    x = rand((2, 300, 400), jnp.float32)
    out = p3_k.transpose2d_batched(x, diagonal=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.swapaxes(np.asarray(x), 1, 2))


ALL_3D_PERMS = [(0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0), (2, 0, 1), (2, 1, 0)]


@pytest.mark.parametrize("perm", ALL_3D_PERMS)
def test_permute3d_all_orders(perm):
    x = rand((6, 24, 136), jnp.float32)
    out = rnd_k.permute_nd(x, perm, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.transpose(np.asarray(x), perm))


@pytest.mark.parametrize(
    "shape,perm",
    [
        ((4, 6, 8, 130), (2, 0, 3, 1)),
        ((4, 6, 8, 130), (1, 0, 2, 3)),
        ((3, 4, 5, 6, 7), (4, 2, 0, 3, 1)),
        ((2, 3, 4, 5, 6, 7), (5, 0, 4, 1, 3, 2)),
        ((8, 16, 131), (0, 1, 2)),
        ((6, 256), (1, 0)),
    ],
)
def test_permute_nd(shape, perm):
    x = rand(shape, jnp.float32)
    out = rnd_k.permute_nd(x, perm, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.transpose(np.asarray(x), perm))


@pytest.mark.parametrize("grid_order", ["in", "out"])
def test_permute_grid_order_policies(grid_order):
    x = rand((4, 5, 6, 64), jnp.float32)
    out = rnd_k.permute_nd(x, (2, 0, 3, 1), grid_order=grid_order, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.transpose(np.asarray(x), (2, 0, 3, 1))
    )


# ---------------------------------------------------------------------------
# §III-C interlace / de-interlace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 3, 4, 5, 8, 9])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interlace_roundtrip(n, dtype):
    arrays = tuple(rand((512,), dtype) for _ in range(n))
    il = il_k.interlace(arrays, interpret=True)
    expect = np.stack([np.asarray(a) for a in arrays], -1).reshape(-1)
    np.testing.assert_array_equal(np.asarray(il), expect)
    back = il_k.deinterlace(il, n, interpret=True)
    for a, b in zip(arrays, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# §III-D stencil
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", [1, 2, 3, 4])
@pytest.mark.parametrize("shape", [(64, 128), (50, 130), (33, 257)])
def test_fd_stencil_orders(order, shape):
    x = rand(shape, jnp.float32)
    offs, wts = ref.fd_stencil_offsets(order)
    got = st_k.stencil2d(x, offs, wts, interpret=True)
    want = ref.stencil2d(x, offs, wts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_stencil_functor_nonlinear():
    x = rand((48, 128), jnp.float32)

    def maxpool_like(shift):
        return jnp.maximum(
            jnp.maximum(shift(0, 0), shift(0, 1)), jnp.maximum(shift(1, 0), shift(1, 1))
        )

    got = st_k.stencil2d_functor(x, maxpool_like, 1, interpret=True)
    want = ref.stencil2d_functor(x, maxpool_like, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_stencil_block_rows_sweep():
    x = rand((64, 128), jnp.float32)
    offs, wts = ref.fd_stencil_offsets(2)
    want = ref.stencil2d(x, offs, wts)
    for br in (8, 16, 32, 64):
        got = st_k.stencil2d(x, offs, wts, block_rows=br, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused flash attention kernel (hillclimb #1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,causal",
    [(2, 4, 2, 128, 128, True), (2, 4, 1, 64, 160, False), (1, 2, 2, 100, 100, True)],
)
def test_flash_kernel_vs_exact(b, hq, hkv, sq, skv, causal):
    from repro.kernels import flash

    d = 32
    q = rand((b, hq, sq, d), jnp.float32)
    k = rand((b, hkv, skv, d), jnp.float32)
    v = rand((b, hkv, skv, d), jnp.float32)
    out = flash.flash_attention(
        q, k, v, causal=causal, block_q=32, block_k=64, interpret=True
    )
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask, logits, -1e30)
    want = jnp.einsum(
        "bhgqk,bhkd->bhgqd", jax.nn.softmax(logits, -1), v
    ).reshape(b, hq, sq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_flash_kernel_model_path(monkeypatch):
    """Model attention routed through the fused kernel == jnp flash path."""
    from repro.models import attention as attn

    q = rand((1, 4, 64, 32), jnp.float32)
    k = rand((1, 2, 64, 32), jnp.float32)
    v = rand((1, 2, 64, 32), jnp.float32)
    base = attn.flash_attention(q, k, v, causal=True, chunk=32)
    monkeypatch.setenv("REPRO_FLASH_KERNEL", "1")
    fused = attn.flash_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base), rtol=2e-4, atol=2e-4)


def test_flash_dma_accounting():
    from repro.kernels import flash

    got = flash.dma_bytes(1, 8, 2, 1024, 1024, 128, 2, block_q=512, block_k=512)
    # nq=nk=2: q 8*2*2*512*128*2, kv 2x, o 8*2*512*128*2
    assert got == (8 * 4 * 512 * 128 * 2) + 2 * (8 * 4 * 512 * 128 * 2) + 8 * 2 * 512 * 128 * 2


@pytest.mark.parametrize("s,bq", [(128, 32), (96, 32), (160, 64)])
def test_flash_triangular_matches_rectangular(s, bq):
    """Triangular-grid causal flash (half the K/V DMA) is bit-exact vs the
    rectangular grid."""
    from repro.kernels import flash

    q = rand((2, 4, s, 32), jnp.float32)
    k = rand((2, 2, s, 32), jnp.float32)
    v = rand((2, 2, s, 32), jnp.float32)
    tri = flash.flash_attention_triangular(q, k, v, block_q=bq, block_k=bq, interpret=True)
    rect = flash.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bq, interpret=True)
    np.testing.assert_array_equal(np.asarray(tri), np.asarray(rect))
