"""Shared model building blocks: norms, embeddings, RoPE, initializers.

Parameters are plain nested dicts of jax.Arrays; every init function has a
matching ``*_pspec`` producing the logical PartitionSpec tree (resolved
against the mesh by ``repro.sharding.partition``).  RoPE uses the planar
half-split from ``repro.core.rearrange`` — a §III-C de-interlace pattern.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import rearrange as rr

Array = jax.Array


def feinsum(eq: str, a: Array, b: Array) -> Array:
    """einsum with fp32 accumulation.  On TPU this is the MXU-native
    bf16-in/f32-out dot (preferred_element_type); the CPU backend cannot
    execute some of those thunks, so inputs are upcast there instead.
    ``REPRO_BF16_DOT=1`` forces the TPU form regardless of backend — the
    dry-run sets it so the lowered HLO is TPU-faithful."""
    if os.environ.get("REPRO_BF16_DOT") == "1" or jax.default_backend() == "tpu":
        return jnp.einsum(eq, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(eq, a.astype(jnp.float32), b.astype(jnp.float32))


@jax.custom_vjp
def bf16_grads(x: Array) -> Array:
    """Identity forward; casts the cotangent to bf16.

    Measured result (EXPERIMENTS §Perf, refuted hypothesis): inserting
    this after the TP projections does NOT shrink the fp32 all-reduces in
    the qwen2 lowering — those reductions are *forward-side* dot outputs
    that XLA reduces in accumulator precision before the bf16 convert.
    Kept as a utility (useful where genuinely fp32 cotangents arise).
    """
    return x


def _bf16_grads_fwd(x):
    return x, None


def _bf16_grads_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grads.defvjp(_bf16_grads_fwd, _bf16_grads_bwd)


#: names accepted by :func:`remat_policy` (SNIPPETS Snippet 2 convention).
REMAT_POLICIES = (
    "nothing_saveable",
    "dots_saveable",
    "dots_with_no_batch_dims_saveable",
    "everything_saveable",
)


def remat_policy(name: str | None):
    """Resolve a remat-policy name to a ``jax.checkpoint`` policy callable.

    ``None`` / ``"none"`` / ``"nothing_saveable"`` map to ``None`` — the
    ``jax.checkpoint`` default, which saves nothing and recomputes the whole
    block on the backward pass (the blockwise-parallel training default:
    peak activation memory is one chunk).  The ``dots*`` policies save
    matmul outputs (recompute only the cheap elementwise tail), and
    ``everything_saveable`` disables rematerialization while keeping the
    chunked structure.  Unknown names raise ``ValueError``.
    """
    if name in (None, "none", "nothing_saveable"):
        return None
    pols = jax.checkpoint_policies
    table = {
        "dots_saveable": pols.dots_saveable,
        "dots_with_no_batch_dims_saveable": pols.dots_with_no_batch_dims_saveable,
        "everything_saveable": pols.everything_saveable,
    }
    if name not in table:
        raise ValueError(
            f"unknown remat policy {name!r}; expected one of {REMAT_POLICIES}"
        )
    return table[name]


def truncated_normal_init(key, shape, scale: float, dtype) -> Array:
    stddev = scale / max(1.0, (shape[-2] if len(shape) > 1 else shape[-1])) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def norm_init(kind: str, d: int) -> dict:
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def apply_norm(kind: str, params: dict, x: Array) -> Array:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# rotary position embedding (planar convention)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, D) with positions (..., S) or (S,).  Planar half-split
    rotation — the de-interlace pattern of paper §III-C."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = rr.rope_halves(x)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    y1 = x1f * cos - x2f * sin
    y2 = x2f * cos + x1f * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_pos(positions: Array, d: int) -> Array:
    """Classic sinusoidal absolute position embedding, (..., S, D)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype) -> dict:
    return {"tok": truncated_normal_init(key, (vocab, d), 1.0, dtype)}


def embed(params: dict, tokens: Array) -> Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: dict, x: Array, head: Array | None = None) -> Array:
    """Logits: tied (embed.T) or separate lm_head (D, V)."""
    w = params["tok"].T if head is None else head
    return jnp.einsum("...d,dv->...v", x, w, preferred_element_type=jnp.float32)
