"""Property-based tests (hypothesis) on the library's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import layout
from repro.core.plan import plan_rearrange
from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


def perms(n):
    return st.permutations(list(range(n)))


shapes_and_perms = st.integers(2, 5).flatmap(
    lambda n: st.tuples(
        st.tuples(*[st.integers(1, 6) for _ in range(n)]),
        st.permutations(list(range(n))),
    )
)


@given(st.integers(1, 6).flatmap(perms))
def test_paper_order_perm_roundtrip(order):
    perm = layout.paper_order_to_perm(order)
    assert sorted(perm) == list(range(len(order)))
    back = layout.perm_to_paper_order(perm)
    assert tuple(back) == tuple(order)


@given(st.integers(1, 6).flatmap(perms))
def test_invert_perm(perm):
    inv = layout.invert_perm(perm)
    assert layout.compose_perm(perm, inv) == tuple(range(len(perm)))
    assert layout.compose_perm(inv, perm) == tuple(range(len(perm)))


@given(shapes_and_perms)
def test_coalesce_preserves_semantics(sp):
    shape, perm = sp
    x = np.arange(int(np.prod(shape))).reshape(shape)
    want = np.transpose(x, perm)
    cshape, cperm, _ = layout.coalesce(shape, perm)
    got = np.transpose(x.reshape(cshape), cperm)
    assert got.size == want.size
    np.testing.assert_array_equal(got.reshape(want.shape), want)


@given(shapes_and_perms)
def test_canonicalize_mode_is_consistent(sp):
    shape, perm = sp
    canon = layout.canonicalize(shape, perm)
    assert canon.mode in ("identity", "copy", "transpose")
    if canon.mode == "transpose":
        # output-fastest axis differs from input-fastest axis
        assert canon.perm[-1] != len(canon.shape) - 1
    if canon.mode == "copy":
        assert canon.perm[-1] == len(canon.shape) - 1


@given(shapes_and_perms)
def test_permute_inverse_is_identity(sp):
    shape, perm = sp
    x = jnp.asarray(np.random.default_rng(0).standard_normal(shape), jnp.float32)
    y = ops.permute(x, perm)
    back = ops.permute(y, layout.invert_perm(perm))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(st.integers(2, 9), st.integers(1, 8))
def test_interlace_deinterlace_roundtrip(n, blocks):
    length = 128 * blocks
    rng = np.random.default_rng(n)
    arrays = [jnp.asarray(rng.standard_normal(length), jnp.float32) for _ in range(n)]
    il = ops.interlace(arrays)
    back = ops.deinterlace(il, n)
    for a, b in zip(arrays, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # interlace element law: out[j*n + k] == arrays[k][j]
    j, k = int(rng.integers(0, length)), int(rng.integers(0, n))
    assert float(il[j * n + k]) == float(arrays[k][j])


@given(st.integers(1, 4))
def test_stencil_linearity(order):
    rng = np.random.default_rng(order)
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    offs, wts = ref.fd_stencil_offsets(order)
    lhs = ref.stencil2d(x + 2.0 * y, offs, wts)
    rhs = ref.stencil2d(x, offs, wts) + 2.0 * ref.stencil2d(y, offs, wts)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


@given(shapes_and_perms)
def test_plan_invariants(sp):
    shape, perm = sp
    plan = plan_rearrange(shape, jnp.float32, perm)
    n = int(np.prod(shape))
    assert plan.bytes_moved == 2 * n * 4
    assert plan.roofline_s >= 0
    assert plan.block_r >= 1 and plan.block_c >= 1


@given(st.permutations(list(range(4))))
def test_kernel_matches_oracle_property(perm):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 4, 5, 16)), jnp.float32)
    from repro.kernels import reorder_nd

    got = reorder_nd.permute_nd(x, tuple(perm), interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.transpose(np.asarray(x), perm)
    )


# ---------------------------------------------------------------------------
# affine recognizer / planner properties (DESIGN.md §14)
# ---------------------------------------------------------------------------

from repro.core import affine  # noqa: E402  (after hypothesis importorskip)


@given(shapes_and_perms)
def test_affine_lift_matches_transpose(sp):
    """recognize -> materialize -> oracle equality: the affine lift of any
    (shape, perm) gathers exactly like jnp.transpose."""
    shape, perm = sp
    amap = layout.to_affine(shape, perm)
    x = np.arange(int(np.prod(shape))).reshape(shape)
    want = np.transpose(x, perm).ravel()
    np.testing.assert_array_equal(x.ravel()[amap.index_vector()], want)


@given(shapes_and_perms)
def test_affine_compose_invert_identity(sp):
    """compose . invert == identity on the permutation class."""
    shape, perm = sp
    amap = layout.to_affine(shape, perm)
    ident = amap.compose(amap.invert())
    np.testing.assert_array_equal(
        ident.index_vector(), np.arange(amap.n_in)
    )


@given(shapes_and_perms)
def test_affine_canonical_agrees_with_canonicalize(sp):
    """canonicalize is a projection of the affine form: same mode; when no
    size-1 axis splits a mergeable run the merged shapes agree exactly (the
    affine merge is strictly stronger across dropped size-1 axes)."""
    shape, perm = sp
    canon = layout.canonicalize(shape, perm)
    acanon = layout.affine_canonical(shape, perm)
    if 1 not in shape:
        assert acanon.mode == canon.mode
        assert acanon.shape == canon.shape
        assert acanon.perm == canon.perm
        assert acanon.rows_axis == canon.rows_axis
        assert acanon.cols_axis == canon.cols_axis
    else:
        assert int(np.prod(acanon.shape)) == int(np.prod(canon.shape))
        if acanon.mode != "identity":
            assert canon.mode != "identity"


@given(st.integers(2, 4096), st.integers(0, 2**31 - 1))
def test_shuffle_map_bijection_roundtrip(n, seed):
    """Seeded shuffle maps are bijections; compose . invert == identity and
    the recognizer recovers an equivalent map from the bare index vector."""
    amap = affine.shuffle_map(n, seed=seed)
    iv = amap.index_vector()
    assert sorted(iv.tolist()) == list(range(n))
    ident = amap.compose(amap.invert())
    np.testing.assert_array_equal(ident.index_vector(), np.arange(n))
    rec = affine.recognize_index_vector(iv)
    assert rec is not None
    np.testing.assert_array_equal(rec.index_vector(), iv)


@given(st.integers(4, 512), st.integers(0, 2**31 - 1))
def test_recognizer_refuses_non_affine(n, seed):
    """Non-affine requests are refused to the generic route: a random
    transposition almost never stays per-digit separable, and whenever the
    recognizer does accept, its map must reproduce the vector exactly."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    a, b = rng.integers(0, n, size=2)
    idx[a], idx[b] = idx[b], idx[a]
    rec = affine.recognize_index_vector(idx)
    if rec is not None:  # accepted: must be exact (a==b or an affine swap)
        np.testing.assert_array_equal(rec.index_vector(), idx)
    # a non-permutation vector is always refused
    if n > 1:
        bad = np.arange(n)
        bad[0] = bad[1]
        assert affine.recognize_index_vector(bad) is None


@given(shapes_and_perms)
def test_plan_source_stamp(sp):
    """Every plan carries a plan_source stamp; shapes without size-1 axes
    must derive analytically (closed-form tile == routed tile)."""
    shape, perm = sp
    plan = plan_rearrange(shape, jnp.float32, perm)
    assert plan.plan_source in ("heuristic", "analytic")
    if 1 not in shape:
        assert plan.plan_source == "analytic"
