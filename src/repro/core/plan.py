"""Rearrangement planner: canonicalize, cost-model, choose kernel + tiles.

The planner is the library's 'auto gridding' (paper §III-A: "gridding and
threading configuration is done automatically based on the data size").
It reports the predicted HBM traffic and roofline time so callers (and the
benchmarks) can compare achieved vs predicted movement.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp

from repro.core import layout
from repro.kernels.tiling import plan_copy_tiles, plan_transpose_tiles

# v5e per-chip hardware constants (also used by utils.roofline)
HBM_GBPS = 819.0
PEAK_BF16_TFLOPS = 197.0
ICI_GBPS_PER_LINK = 50.0


@dataclass(frozen=True)
class RearrangePlan:
    mode: str  # identity | copy | transpose
    canonical_shape: tuple[int, ...]
    canonical_perm: tuple[int, ...]
    block_r: int
    block_c: int
    bytes_moved: int  # read + write
    roofline_s: float  # bytes / HBM bandwidth (one chip)

    def describe(self) -> str:
        return (
            f"{self.mode}: shape={self.canonical_shape} perm={self.canonical_perm} "
            f"tiles=({self.block_r},{self.block_c}) "
            f"{self.bytes_moved/1e6:.2f} MB moved, "
            f"roofline {self.roofline_s*1e6:.1f} us @ {HBM_GBPS} GB/s"
        )


def plan_rearrange(shape: Sequence[int], dtype, perm: Sequence[int]) -> RearrangePlan:
    canon = layout.canonicalize(shape, perm)
    itemsize = jnp.dtype(dtype).itemsize
    n_elems = 1
    for s in shape:
        n_elems *= int(s)
    bytes_moved = 2 * n_elems * itemsize  # read once + write once

    if canon.mode == "identity" or canon.rows_axis is None:
        tp = plan_copy_tiles(
            max(n_elems // max(shape[-1], 1), 1), shape[-1] if shape else 1, dtype
        )
    elif canon.mode == "copy":
        tp = plan_copy_tiles(
            canon.shape[canon.rows_axis], canon.shape[canon.cols_axis], dtype
        )
    else:
        tp = plan_transpose_tiles(
            canon.shape[canon.rows_axis], canon.shape[canon.cols_axis], dtype
        )
    return RearrangePlan(
        mode=canon.mode,
        canonical_shape=canon.shape,
        canonical_perm=canon.perm,
        block_r=tp.block_r,
        block_c=tp.block_c,
        bytes_moved=bytes_moved,
        roofline_s=bytes_moved / (HBM_GBPS * 1e9),
    )
