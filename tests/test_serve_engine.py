"""Serving-engine suite (ISSUE 6): split-KV decode attention, ragged
packed prefill, chunked prefill, and the continuous-batching engine.

Layers covered, bottom-up:

* `kernels.flash.flash_decode` — tolerance-banded vs the jnp one-shot
  oracle across fp32/bf16 with random per-slot lengths; jaxpr-asserted
  kernel counts (two ``pallas_call``s end to end, the stage-2 combine
  exactly ONE); plan identity.
* `core.index_plan.ragged_layout` / ``ragged_rows`` plans — geometry,
  zero-length sequences, masked-only validation.
* `models.transformer.prefill_ragged` + the engine's unpack — packed KV
  rows and logits match per-prompt prefill (pack/unpack oracle
  equivalence).
* `models.transformer.decode_step` with a (B,) position vector — slots
  at different positions decode exactly like single-slot scalar decode
  (the seed's max-pos bug).
* `serve.engine.Engine` — admit returns the slot, staggered multi-tenant
  traffic matches a clean per-request greedy reference in ragged,
  ragged+chunked and bucket-capacity terms, run() retires everything.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import index_plan as ip
from repro.kernels import flash
from repro.models import attention
from repro.models import transformer as tf
from repro.serve.engine import Engine, Request, _write_ragged, _write_slot

KEY = jax.random.PRNGKey(0)


# -- split-KV decode kernel --------------------------------------------------


def _rand_qkv(key, b, hq, hkv, s, d, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, 1, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, s, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize(
    "dtype,tol",
    [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)],
    ids=["fp32", "bf16"],
)
def test_flash_decode_matches_oneshot_oracle(pallas_interpret, dtype, tol):
    b, hq, hkv, s, d = 3, 8, 2, 100, 32
    q, k, v = _rand_qkv(KEY, b, hq, hkv, s, d, dtype)
    lens = jnp.asarray([1, 37, 100], jnp.int32)  # random-ish per-slot ring fill
    got = flash.flash_decode(q, k, v, lengths=lens, num_splits=3, block_k=32)
    ref = attention.decode_attention(q, k, v, length=lens, engine="oneshot")
    assert got.shape == ref.shape == (b, hq, 1, d)
    err = jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) <= tol, float(err)


def test_flash_decode_plan_geometry_and_identity():
    plan = flash.plan_flash_decode(4, 16, 4, 512, 64, jnp.bfloat16)
    assert plan is flash.plan_flash_decode(4, 16, 4, 512, 64, jnp.bfloat16)
    ns, bk = plan.num_splits, plan.block_k
    assert ns >= 1 and bk >= 1 and bk <= 512
    assert plan.bytes_moved > 0 and plan.roofline_s > 0
    assert "flash_decode" in plan.describe()


def test_flash_decode_jaxpr_kernel_counts():
    # end to end: stage-1 split kernel + stage-2 combine = TWO pallas_calls;
    # the combine alone is exactly ONE (the fused mid-softmax reduce)
    b, hq, hkv, s, d = 2, 4, 2, 64, 16
    q, k, v = _rand_qkv(KEY, b, hq, hkv, s, d, jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    full = jax.make_jaxpr(
        lambda a, c, w, l: flash.flash_decode(
            a, c, w, lengths=l, num_splits=2, block_k=16, interpret=True
        )
    )(q, k, v, lens)
    assert len(re.findall(r"\bpallas_call\b", str(full))) == 2
    g = hq // hkv
    mid_o = jnp.zeros((b * hkv, 2, g, d), jnp.float32)
    mid_m = jnp.zeros((b * hkv, 2, g), jnp.float32)
    mid_l = jnp.zeros((b * hkv, 2, g), jnp.float32)
    comb = jax.make_jaxpr(
        lambda o, m, l: flash.decode_combine(o, m, l, num_splits=2, interpret=True)
    )(mid_o, mid_m, mid_l)
    assert len(re.findall(r"\bpallas_call\b", str(comb))) == 1


def test_decode_attention_per_slot_lengths():
    # vector lengths mask per slot: each row equals its scalar-length result
    b, hq, hkv, s, d = 3, 4, 2, 48, 16
    q, k, v = _rand_qkv(KEY, b, hq, hkv, s, d, jnp.float32)
    lens = jnp.asarray([5, 20, 48], jnp.int32)
    got = attention.decode_attention(q, k, v, length=lens, engine="oneshot")
    for i, ln in enumerate([5, 20, 48]):
        one = attention.decode_attention(
            q[i : i + 1], k[i : i + 1], v[i : i + 1], length=ln, engine="oneshot"
        )
        assert jnp.allclose(got[i], one[0], atol=1e-6)


# -- ragged layout + ragged_rows plans ---------------------------------------


def test_ragged_layout_geometry():
    lay = ip.ragged_layout((3, 0, 5), bucket=8)
    assert lay.total == 8 and lay.t_pad == 8
    assert lay.indptr == (0, 3, 3, 8)
    assert lay.seg_ids.tolist() == [0, 0, 0, 2, 2, 2, 2, 2]
    assert lay.positions.tolist() == [0, 1, 2, 0, 1, 2, 3, 4]
    unp = lay.unpack_index(4)
    assert unp[0].tolist() == [0, 1, 2, -1]
    assert unp[1].tolist() == [-1, -1, -1, -1]  # zero-length: all sentinels
    assert unp[2].tolist() == [3, 4, 5, 6]
    assert ip.ragged_layout((3, 0, 5), bucket=8) is lay  # memoized


def test_ragged_rows_plan_requires_mask():
    with pytest.raises(ValueError, match="masked"):
        ip.plan_index_op((64, 16), jnp.float32, 32, "ragged_rows")
    plan = ip.plan_index_op((64, 16), jnp.float32, 32, "ragged_rows", masked=True)
    assert plan.semantics == "ragged_rows"
    assert plan is ip.plan_index_op(
        (64, 16), jnp.float32, 32, "ragged_rows", masked=True
    )


# -- packed prefill vs per-prompt prefill ------------------------------------


@pytest.fixture(scope="module")
def qwen():
    cfg = configs.get_config("qwen2-7b-smoke")
    params = tf.init_params(KEY, cfg)
    return cfg, params


def test_prefill_ragged_pack_unpack_oracle(qwen):
    cfg, params = qwen
    assert tf.supports_ragged(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (5, 9)]
    lay = ip.ragged_layout(tuple(len(p) for p in prompts), bucket=8)
    toks = np.zeros((1, lay.t_pad), np.int32)
    for j, p in enumerate(prompts):
        toks[0, lay.indptr[j] : lay.indptr[j] + len(p)] = p
    last = np.asarray(lay.last_ix, np.int32)
    logits, packed = tf.prefill_ragged(
        params, cfg, jnp.asarray(toks), jnp.asarray(lay.seg_ids),
        jnp.asarray(lay.positions), jnp.asarray(last),
    )
    s_max = 32
    cache = _write_ragged(tf.init_cache(cfg, 2, s_max), packed, [0, 1], lay, s_max)
    for j, p in enumerate(prompts):
        ref_logits, ref_cache = tf.prefill(params, cfg, jnp.asarray(p)[None])
        # per-sequence last-token logits agree with the unpacked prompt
        assert int(jnp.argmax(logits[j])) == int(jnp.argmax(ref_logits[0]))
        assert jnp.allclose(
            logits[j].astype(jnp.float32),
            ref_logits[0].astype(jnp.float32),
            atol=2e-2,
        )
        # unpacked KV rows [0, len) match; the ring tail is zero-filled
        for got, ref in zip(jax.tree.leaves(cache), jax.tree.leaves(ref_cache)):
            rows = got[:, j, :, : len(p)].astype(jnp.float32)
            want = ref[:, 0, :, : len(p)].astype(jnp.float32)
            assert jnp.allclose(rows, want, atol=2e-2)
            tail = got[:, j, :, len(p) :].astype(jnp.float32)
            assert jnp.all(tail == 0)


def test_decode_step_per_slot_positions(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (6, 13)]
    s_max = 32
    cache = tf.init_cache(cfg, 2, s_max)
    for j, p in enumerate(prompts):
        _, c1 = tf.prefill(params, cfg, jnp.asarray(p)[None])
        cache = _write_slot(cache, c1, j, s_max)
    toks = jnp.asarray([3, 7], jnp.int32)
    pos = jnp.asarray([len(p) for p in prompts], jnp.int32)
    logits, _ = tf.decode_step(params, cfg, toks, cache, pos)
    for j, p in enumerate(prompts):
        ring1 = _write_slot(tf.init_cache(cfg, 1, s_max),
                            tf.prefill(params, cfg, jnp.asarray(p)[None])[1],
                            0, s_max)
        ref, _ = tf.decode_step(
            params, cfg, toks[j : j + 1], ring1, jnp.int32(len(p))
        )
        assert jnp.allclose(
            logits[j].astype(jnp.float32), ref[0].astype(jnp.float32), atol=2e-2
        ), f"slot {j} decoded against the wrong per-slot length"


# -- the engine --------------------------------------------------------------


def _reference_greedy(cfg, params, prompt, max_new, s_max):
    """Clean single-request greedy decode: unpadded prefill + scalar-pos
    stepwise decode (the pre-engine model path)."""
    logits, c1 = tf.prefill(params, cfg, jnp.asarray(prompt)[None])
    ring = _write_slot(tf.init_cache(cfg, 1, s_max), c1, 0, s_max)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(out) < max_new and pos < s_max:
        lg, ring = tf.decode_step(
            params, cfg, jnp.asarray([out[-1]], np.int32), ring, jnp.int32(pos)
        )
        pos += 1
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.fixture(scope="module")
def served(qwen):
    """Shared prompts + per-request reference outputs."""
    cfg, params = qwen
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
               for n in (7, 19, 3, 12)]
    refs = [_reference_greedy(cfg, params, p, 5, 64) for p in prompts]
    return prompts, refs


@pytest.mark.parametrize(
    "mode,chunk", [("ragged", None), ("ragged", 8), ("bucket", None)],
    ids=["ragged", "ragged_chunked", "bucket"],
)
def test_engine_staggered_traffic(qwen, served, mode, chunk):
    cfg, params = qwen
    prompts, refs = served
    engine = Engine(cfg, params, batch_slots=2, s_max=64, prompt_bucket=16,
                    prefill_mode=mode, chunk=chunk)
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    done = engine.run(reqs)
    assert len(done) == len(prompts)  # slot reuse: 4 requests through 2 slots
    assert all(r.done and r.slot is None for r in done)
    assert all(len(r.out) == 5 for r in done)
    if mode == "ragged":
        # staggered admissions at different per-slot positions reproduce
        # the clean per-request greedy decode exactly
        for r in done:
            assert r.out == refs[r.rid], (mode, chunk, r.rid)


def test_engine_admit_returns_slot(qwen):
    cfg, params = qwen
    engine = Engine(cfg, params, batch_slots=2, s_max=64, prompt_bucket=16)
    p = np.arange(4, dtype=np.int32) % cfg.vocab
    r0, r1 = Request(rid=0, prompt=p), Request(rid=1, prompt=p)
    s0 = engine.admit(r0)
    s1 = engine.admit(r1)
    assert sorted([s0, s1]) == [0, 1]
    assert r0.slot == s0 and r1.slot == s1
    assert engine.admit(Request(rid=2, prompt=p)) is None  # full
    assert engine.free_slots() == []


def test_engine_admission_validation(qwen):
    cfg, params = qwen
    engine = Engine(cfg, params, batch_slots=2, s_max=32, prompt_bucket=16)
    with pytest.raises(ValueError, match="empty prompt"):
        engine.admit(Request(rid=0, prompt=np.zeros(0, np.int32)))
    with pytest.raises(ValueError, match="does not fit"):
        engine.admit(Request(rid=1, prompt=np.zeros(40, np.int32)))
    with pytest.raises(ValueError, match="ragged"):
        Engine(cfg, params, prefill_mode="bucket", chunk=8)


def test_engine_step_returns_finished(qwen):
    cfg, params = qwen
    engine = Engine(cfg, params, batch_slots=2, s_max=64, prompt_bucket=16)
    p = (np.arange(5) % cfg.vocab).astype(np.int32)
    fast = Request(rid=0, prompt=p, max_new=2)
    slow = Request(rid=1, prompt=p, max_new=4)
    engine.admit_batch([fast, slow])  # each already holds its first token
    first = engine.step()
    assert first == [fast]  # retires at max_new=2, slot freed
    assert engine.live[fast.slot if fast.slot is not None else 0] is None
    rest = []
    for _ in range(4):
        rest.extend(engine.step())
    assert rest == [slow]


def test_engine_bucket_mode_for_non_ragged_arch():
    cfg = configs.get_config("xlstm-125m-smoke")
    assert not tf.supports_ragged(cfg)
    params = tf.init_params(KEY, cfg)
    engine = Engine(cfg, params, batch_slots=2, s_max=64, prompt_bucket=16)
    assert engine.mode == "bucket"  # auto-fallback
    with pytest.raises(ValueError, match="attention-only"):
        Engine(cfg, params, prefill_mode="ragged")


def test_serve_flags_tpu_gated(monkeypatch):
    """The XLA inference preset must never reach a non-TPU backend:
    unknown flags abort XLA at startup.  Explicit platform env decides;
    user-set flags always win over the preset."""
    from repro.launch import xla_flags

    monkeypatch.setenv("JAX_PLATFORMS", "cpu")
    monkeypatch.setenv("XLA_FLAGS", "")
    assert xla_flags.apply_serve_flags(force=True) is None

    monkeypatch.setenv("JAX_PLATFORMS", "tpu")
    monkeypatch.setenv("XLA_FLAGS", "--xla_tpu_rwb_fusion=true")
    merged = xla_flags.apply_serve_flags(force=True)
    assert "--xla_tpu_scoped_vmem_limit_kib=28672" in merged
    assert merged.count("rwb_fusion") == 1  # the user's value survives

    # opt-in: without force, REPRO_SERVE_FLAGS gates the whole preset
    monkeypatch.delenv("REPRO_SERVE_FLAGS", raising=False)
    assert xla_flags.apply_serve_flags() is None
