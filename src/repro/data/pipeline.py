"""Data pipeline: deterministic, stateless-resumable token streams.

Fault-tolerance contract: a batch is a pure function of (seed, step,
host_shard) — restoring from a checkpoint at step N resumes the exact
stream with NO pipeline state to persist, and elastically rescaled
runs re-derive their shard from the new topology.

Sources:
  SyntheticSource  — hash-derived tokens (benchmarks, smoke tests)
  MemmapSource     — packed uint16/uint32 token file via np.memmap
Both emit {tokens, labels} of shape (batch, seq) with next-token labels.
"""

from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from pathlib import Path

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    """Stream geometry + sharding: each host reads ``batch // n_hosts``
    rows of its own shard, addressed purely by (seed, step, host_id)."""

    batch: int
    seq: int
    vocab: int
    seed: int = 0
    path: str | None = None  # memmap file; None -> synthetic
    n_hosts: int = 1
    host_id: int = 0


class SyntheticSource:
    """tokens[i] = philox(seed, step, row) % vocab — O(1) random access."""

    def __init__(self, dc: DataConfig):
        self.dc = dc

    def batch_at(self, step: int) -> dict:
        """The (tokens, labels) batch for ``step`` — pure in (seed, step,
        host shard); no stream state."""
        dc = self.dc
        rows = dc.batch // dc.n_hosts
        rng = np.random.Generator(
            np.random.Philox(key=dc.seed, counter=[0, 0, dc.host_id, step])
        )
        toks = rng.integers(0, dc.vocab, (rows, dc.seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapSource:
    """Fixed-stride window reader over a flat token file; step-addressed."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        path = Path(dc.path)
        dtype = np.uint32 if path.stat().st_size % 4 == 0 else np.uint16
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.n_tokens = len(self.data)

    def batch_at(self, step: int) -> dict:
        """The (tokens, labels) windows for ``step``, striding the flat
        token file by host shard (wraps modulo the file)."""
        dc = self.dc
        rows = dc.batch // dc.n_hosts
        span = dc.seq + 1
        n_windows = self.n_tokens // span
        out = np.empty((rows, span), np.int32)
        for r in range(rows):
            w = (step * dc.batch + dc.host_id * rows + r) % n_windows
            out[r] = self.data[w * span : (w + 1) * span]
        out %= dc.vocab
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}


def make_source(dc: DataConfig):
    """Pick the source for ``dc``: memmap when a path is set, else
    synthetic."""
    return MemmapSource(dc) if dc.path else SyntheticSource(dc)


class Prefetcher:
    """Host-side prefetch thread: hides batch construction behind step
    execution (the CPU-side analogue of the paper's DMA double buffering)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.queue: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.queue.put((step, batch), timeout=0.1)
                    break
                except queue_mod.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        """Block for the next (step, batch) pair in stream order."""
        return self.queue.get()

    def close(self):
        """Stop the prefetch thread (idempotent)."""
        self._stop.set()
