"""Paper Table 2: generic reorder on 3-/4-/5-D data (paper's exact rows)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, smoke, time_fn
from repro.core import layout
from repro.core.plan import plan_rearrange
from repro.kernels import ops


def rr_plan(shape, perm):
    return plan_rearrange(shape, jnp.float32, perm)


def _rows() -> list[tuple]:
    """(paper order vector, shape) — Table 2 rows (scaled down in smoke)."""
    s, v = (32, 4) if smoke() else (256, 16)
    return [
        ([1, 0, 2], (s, s, s)),
        ([1, 0, 2, 3], (s, s, s, 1)),
        ([3, 2, 0, 1], (s, s, 1, s)),
        ([3, 0, 2, 1, 4], (s, v, 1, s, v)),
    ]


def run() -> list[str]:
    out = []
    rng = np.random.default_rng(0)
    for order, shape in _rows():
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        perm = layout.paper_order_to_perm(order)
        fn = jax.jit(lambda a, p=perm: ops.permute(a, p))
        t = time_fn(fn, x)
        canon = layout.canonicalize(shape, perm)
        plan = rr_plan(shape, perm)
        out.append(
            row(
                f"reorder_{'-'.join(map(str, order))}",
                t,
                2 * x.nbytes,
                f"[{plan.mode}, coalesced {len(canon.shape)}D]",
                plan_mode=plan.mode,
                kernel=plan.kernel,
                measured="pallas" if ops.use_pallas() else "xla_oracle",
            )
        )
    return out
