"""Batched 2-D transpose — the building block of every reorder (paper §III-B).

The paper's 3D Permute kernel handles a permutation as "a set of batched 2D
data movement operations" in the plane spanned by the fastest-changing input
and output dimensions, staged through shared memory with 32x32 tiles so both
the global load and the global store are coalesced.

TPU-native version:
* the (R, C) plane is tiled into (block_r, block_c) VMEM blocks; the
  transpose happens inside VMEM (VREG shuffles by the VPU) — both the
  HBM->VMEM load and the VMEM->HBM store move full lane-aligned tiles,
  the TPU equivalent of "coalesced on read AND write";
* the paper's *diagonalized CUDA-block ordering* (partition-camping
  avoidance) is kept as a selectable grid-walk policy: the (i, j) tile walk
  is remapped to (i, (i + j) % nC) on both sides.  On TPU, HBM channel
  interleaving is handled by hardware, so this is measured as a policy knob
  rather than assumed to help (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (
    cdiv,
    force_interpret,
    plan_transpose_tiles,
    plan_transpose_vec_tiles,
)


def _transpose_kernel(x_ref, o_ref):
    # block shapes: x (1, br, bc) -> o (1, bc, br)
    o_ref[0, :, :] = x_ref[0, :, :].T


def _dim_semantics(n: int, parallel: bool):
    kind = pltpu.PARALLEL if parallel else pltpu.ARBITRARY
    try:
        return pltpu.CompilerParams(dimension_semantics=(kind,) * n)
    except Exception:  # pragma: no cover - API drift guard
        return None


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_c", "diagonal", "interpret")
)
def transpose2d_batched(
    x: jax.Array,
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    diagonal: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, R, C) -> (B, C, R) via VMEM-staged tiled transpose."""
    if x.ndim != 3:
        raise ValueError(f"expected (B, R, C), got {x.shape}")
    B, R, C = x.shape
    plan = plan_transpose_tiles(R, C, x.dtype)
    br = block_r or plan.block_r
    bc = block_c or plan.block_c
    nR, nC = cdiv(R, br), cdiv(C, bc)

    if diagonal and nC > 1:

        def in_map(b, i, j):
            return (b, i, lax.rem(i + j, nC))

        def out_map(b, i, j):
            return (b, lax.rem(i + j, nC), i)

    else:

        def in_map(b, i, j):
            return (b, i, j)

        def out_map(b, i, j):
            return (b, j, i)

    interpret = force_interpret() if interpret is None else interpret
    params = _dim_semantics(3, parallel=not diagonal)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        _transpose_kernel,
        grid=(B, nR, nC),
        in_specs=[pl.BlockSpec((1, br, bc), in_map)],
        out_specs=pl.BlockSpec((1, bc, br), out_map),
        out_shape=jax.ShapeDtypeStruct((B, C, R), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x)


def _transpose_vec_kernel(x_ref, o_ref):
    # block shapes: x (1, br, bc, bv) -> o (1, bc, br, bv)
    o_ref[0] = jnp.transpose(x_ref[0], (1, 0, 2))


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_c", "block_v", "interpret")
)
def transpose2d_batched_vec(
    x: jax.Array,
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    block_v: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, R, C, V) -> (B, C, R, V): batched middle-axes transpose with a
    contiguous vector payload.

    This is the planner's target for the whole (B, S, H, D)-swap family
    (split_heads / merge_heads / space_to_depth after axis collapsing): V is
    the collapsed identity tail, so both the load and the store move runs of
    V contiguous elements — the (R, C) plane transposes whole V-vectors
    instead of scalars, and the lane dim never changes sides.
    """
    if x.ndim != 4:
        raise ValueError(f"expected (B, R, C, V), got {x.shape}")
    B, R, C, V = x.shape
    plan = plan_transpose_vec_tiles(R, C, V, x.dtype)
    br = min(block_r or plan.block_r, R)
    bc = min(block_c or plan.block_c, C)
    bv = min(block_v or plan.block_v, V)
    nR, nC, nV = cdiv(R, br), cdiv(C, bc), cdiv(V, bv)

    def in_map(b, i, j, v):
        return (b, i, j, v)

    def out_map(b, i, j, v):
        return (b, j, i, v)

    interpret = force_interpret() if interpret is None else interpret
    params = _dim_semantics(4, parallel=True)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        _transpose_vec_kernel,
        grid=(B, nR, nC, nV),
        in_specs=[pl.BlockSpec((1, br, bc, bv), in_map)],
        out_specs=pl.BlockSpec((1, bc, br, bv), out_map),
        out_shape=jax.ShapeDtypeStruct((B, C, R, V), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x)


def transpose2d(
    x: jax.Array,
    *,
    block_r: int | None = None,
    block_c: int | None = None,
    diagonal: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """(R, C) -> (C, R)."""
    return transpose2d_batched(
        x[None],
        block_r=block_r,
        block_c=block_c,
        diagonal=diagonal,
        interpret=interpret,
    )[0]
