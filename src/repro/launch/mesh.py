"""Production mesh construction (16x16 single pod / 2x16x16 multi-pod).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the dry-run's forced 512-device
initialization to happen first).
"""

from __future__ import annotations

import os

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    jax 0.4.37 lacks ``jax.sharding.AxisType`` (it landed in 0.5.x); on
    such builds the ``axis_types`` kwarg is omitted — every axis is Auto
    by default there, so semantics are identical.  All mesh construction
    in the repo (and the subprocess test harnesses) routes through this
    shim instead of touching ``AxisType`` directly.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def set_mesh_compat(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.sharding.set_mesh`` where present; on jax 0.4.37 the ``Mesh``
    object is itself the context manager (the legacy physical-mesh
    resource env), which is what explicit-sharding jits need there.
    """
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, across jax versions
    (0.4.37 ships it as ``jax.experimental.shard_map.shard_map`` with the
    ``check_rep`` spelling of ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def axis_size_compat(axis_name) -> int:
    """Static size of a named mapped axis, across jax versions
    (``jax.lax.axis_size`` is absent on 0.4.37, where
    ``jax.core.axis_frame(name)`` returns the size directly)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax import core as _core

    return _core.axis_frame(axis_name)


def ring_perm(n: int, *, reverse: bool = False) -> list[tuple[int, int]]:
    """``ppermute`` pairs for a ring of ``n`` shards.

    Forward (default) sends shard ``i`` -> ``i+1 (mod n)`` — the receiver
    sees its *predecessor's* rows, i.e. this is how a shard obtains its TOP
    halo from the shard above.  ``reverse=True`` sends ``i`` -> ``i-1`` (the
    BOTTOM halo, from the shard below).  Used by the §10 halo exchange.
    """
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def fake_device_env(n: int = 8) -> dict:
    """Environment entries forcing ``n`` host (CPU) devices — the recipe for
    verifying every mesh-aware code path in this repo without a TPU::

        env = {**os.environ, **fake_device_env(8), "PYTHONPATH": "src"}
        subprocess.run([sys.executable, "-m", "pytest", "tests/test_dist_plan.py"],
                       env=env)

    Must reach the child process before jax initializes its backends, which
    is why tests/benchmarks apply it to a *subprocess* rather than mutating
    their own environment.  Any XLA_FLAGS already in this process's
    environment are preserved (prepended-to, not replaced).
    """
    flags = f"--xla_force_host_platform_device_count={int(n)}"
    existing = os.environ.get("XLA_FLAGS", "")
    return {"XLA_FLAGS": f"{flags} {existing}".strip()}


def make_production_mesh(*, multi_pod: bool = False):
    """The production topology: 16x16 (data, model) single pod, or
    2x16x16 (pod, data, model) when ``multi_pod`` — the mesh the launcher
    dry-run compiles against."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Degenerate mesh over whatever devices exist (smoke/e2e runs)."""
    n = len(jax.devices())
    return make_mesh_compat((n, 1), ("data", "model"))


def mesh_axes_info(mesh) -> dict:
    """Summarize a mesh as the plain dict the sharding rules consume
    (axis names plus per-axis sizes; missing axes report size 1)."""
    names = mesh.axis_names
    return {
        "model": "model",
        "data": "data",
        "model_size": mesh.shape["model"] if "model" in names else 1,
        "data_size": mesh.shape["data"] if "data" in names else 1,
        "pod_size": mesh.shape["pod"] if "pod" in names else 1,
        "multi_pod": "pod" in names,
    }


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the batch dimension shards over (pod+data when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
