"""Checkpointing: async, atomic, elastic-restore.

Layout: <dir>/step_<N>/
  manifest.json   — flat path -> {shape, dtype, file}, plus step + config
  <leaf>.npy      — one file per pytree leaf (host-gathered)

Fault-tolerance properties:
  * atomic publish — written to step_<N>.tmp, fsync'd, then os.rename;
    a crash mid-write never corrupts the latest checkpoint;
  * async — the save runs on a worker thread over host copies, so the
    train loop donates its buffers and keeps stepping;
  * elastic restore — leaves are loaded host-side and device_put with
    whatever shardings the NEW mesh prescribes (the mesh may have a
    different data-axis size than the one that saved);
  * retention — keep_last newest checkpoints survive, older are pruned.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16 natively: store as uint16 + logical dtype
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_into(skeleton, flat: dict, prefix=""):
    if isinstance(skeleton, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
            for k, v in skeleton.items()
        }
    if isinstance(skeleton, (list, tuple)):
        t = [
            _unflatten_into(v, flat, f"{prefix}/{i}")
            for i, v in enumerate(skeleton)
        ]
        return type(skeleton)(t)
    return flat[prefix]


class Checkpointer:
    """Filesystem checkpointer: atomic per-step directories of .npy leaves
    with a JSON manifest, optional async host-side writes, and pruning to
    the last ``keep_last`` steps.  ``restore`` can device_put into new
    shardings (the elastic-resharding path)."""

    def __init__(self, directory: str, *, keep_last: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree) -> None:
        """Snapshot ``tree`` at ``step`` (async when configured; the host
        copy is taken synchronously so callers may mutate after return)."""
        # host-gather while the caller still owns the buffers
        host = {p: np.asarray(jax.device_get(l)) for p, l in _flatten(tree)}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        """Block until any in-flight async save has landed."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for i, (path, arr) in enumerate(host.items()):
            fname = f"leaf_{i:05d}.npy"
            logical = str(arr.dtype)
            if logical in _EXOTIC:
                np.save(tmp / fname, arr.view(_EXOTIC[logical]))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"][path] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": logical,
            }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        """Sorted list of complete (manifest-bearing) checkpoint steps."""
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        """Most recent complete step, or ``None`` if no checkpoint exists."""
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, skeleton, shardings=None):
        """Load into the structure of ``skeleton``; device_put with
        ``shardings`` (same pytree structure) when given — this is the
        elastic-resharding path."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if meta["dtype"] in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
            flat[path] = arr
        tree = _unflatten_into(skeleton, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings
            )
        return tree
