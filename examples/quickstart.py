"""Quickstart: the data-rearrangement library in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout, rearrange as rr, stencil as st

rng = np.random.default_rng(0)

# --- permute: paper order-vector convention or numpy perms ----------------
x = jnp.asarray(rng.standard_normal((128, 256, 512)), jnp.float32)
y = rr.permute_order(x, [1, 0, 2])  # paper Table 1 row 3
assert y.shape == (128, 512, 256)
print("permute [1 0 2]:", x.shape, "->", y.shape)
print("  planner:", rr.plan(x, layout.paper_order_to_perm([1, 0, 2])).describe())

# --- generic N->M reorder (paper Table 2) ----------------------------------
z = jnp.asarray(rng.standard_normal((256, 16, 1, 256, 16)), jnp.float32)
w = rr.permute_order(z, [3, 0, 2, 1, 4])
print("reorder 5-D:", z.shape, "->", w.shape)

# --- interlace / de-interlace (paper §III-C) --------------------------------
re_, im = jnp.asarray(rng.standard_normal((2, 4096)), jnp.float32)
packed = rr.interlace([re_, im])  # complex AoS layout
re2, im2 = rr.deinterlace(packed, 2)
np.testing.assert_array_equal(np.asarray(re_), np.asarray(re2))
print("interlace roundtrip ok:", packed.shape)

# --- stencils as objects (paper §III-D functors) ----------------------------
img = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
lap = st.fd_laplacian(2)  # 2nd-order accurate 2-D Laplacian
smooth = st.box_blur(1)
print("laplacian:", lap(img).shape, "| blur:", smooth(img).shape)

# arbitrary (non-linear) functor — compiled into the kernel at trace time
def sobel_mag(shift):
    gx = shift(-1, 1) + 2 * shift(0, 1) + shift(1, 1) \
       - shift(-1, -1) - 2 * shift(0, -1) - shift(1, -1)
    gy = shift(1, -1) + 2 * shift(1, 0) + shift(1, 1) \
       - shift(-1, -1) - 2 * shift(-1, 0) - shift(-1, 1)
    return jnp.sqrt(gx * gx + gy * gy)

edges = st.apply_functor(img, sobel_mag, radius=1)
print("sobel functor:", edges.shape)

# --- fused stencil programs (temporal blocking, DESIGN.md §9) ---------------
# blur-then-laplacian, 3 fused sweeps each: ONE kernel, one HBM round trip,
# any of the four boundary modes (zero | nearest | reflect | periodic)
prog = smooth.then(lap).repeat(3)
out = prog(img, boundary="reflect")
plan = prog.compile(img.shape, img.dtype, boundary="reflect")
print("stencil program:", out.shape)
print("  planner:", plan.describe())

# --- model-facing helpers (how the LM framework uses the library) -----------
h = jnp.asarray(rng.standard_normal((2, 16, 64)), jnp.float32)
heads = rr.split_heads(h, 4)           # (B,S,H*D) -> (B,H,S,D)
back = rr.merge_heads(heads)
np.testing.assert_allclose(np.asarray(h), np.asarray(back))
print("attention head permutes ok:", heads.shape)
print("\nquickstart complete.")
