"""Step functions + abstract input specs for every (arch x shape cell).

``input_specs`` returns weak-type-correct ShapeDtypeStructs (zero device
allocation) plus the matching NamedShardings; ``make_*_step`` return the
jit-able step callables the dry-run lowers and the trainer executes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch import mesh as meshlib
from repro.models import transformer as tf
from repro.optim import adamw
from repro.sharding import partition

Array = jax.Array


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract (ShapeDtypeStruct) train-step batch for one shape cell."""
    b, s = cell.global_batch, cell.seq_len
    out = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.encoder_layers or cfg.n_frontend_tokens:
        out["frontend"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return out


def prefill_inputs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract prefill-step batch (tokens + optional frontend stream)."""
    b, s = cell.global_batch, cell.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.encoder_layers or cfg.n_frontend_tokens:
        out["frontend"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return out


def decode_inputs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract decode-step batch: one token per row plus the KV cache."""
    b, s = cell.global_batch, cell.seq_len
    cache = jax.eval_shape(lambda: tf.init_cache(cfg, b, s))
    out = {
        "token": _sds((b,), jnp.int32),
        "cache": cache,
        "pos": _sds((), jnp.int32),
    }
    if cfg.encoder_layers or cfg.n_frontend_tokens:
        out["frontend_src"] = _sds(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
        )
    return out


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract inputs for any shape-cell kind (train/prefill/decode)."""
    if cell.kind == "train":
        return train_inputs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_inputs(cfg, cell)
    return decode_inputs(cfg, cell)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def input_shardings(cfg: ModelConfig, cell: ShapeCell, mesh) -> dict:
    """NamedShardings matching :func:`input_specs`: batch over (pod, data),
    decode-cache leaves per :func:`partition.cache_leaf_spec`."""
    info = meshlib.mesh_axes_info(mesh)
    baxes = partition.batch_pspec(cell.global_batch, mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    if cell.kind in ("train", "prefill"):
        out = {
            "tokens": ns(P(baxes, None)),
        }
        if cell.kind == "train":
            out["labels"] = ns(P(baxes, None))
        if cfg.encoder_layers or cfg.n_frontend_tokens:
            out["frontend"] = ns(P(baxes, None, None))
        return out
    # decode
    cache_shapes = jax.eval_shape(lambda: tf.init_cache(cfg, cell.global_batch, cell.seq_len))
    cache_spec = jax.tree.map(
        lambda l: ns(
            partition.cache_leaf_spec(
                tuple(l.shape), baxes, model_size=info["model_size"]
            )
        ),
        cache_shapes,
    )
    out = {
        "token": ns(P(baxes)),
        "cache": cache_spec,
        "pos": ns(P()),
    }
    if cfg.encoder_layers or cfg.n_frontend_tokens:
        out["frontend_src"] = ns(P(baxes, None, None))
    return out


def param_shardings(cfg: ModelConfig, mesh) -> Any:
    """NamedSharding tree for the params (partition rules on this mesh)."""
    info = meshlib.mesh_axes_info(mesh)
    shapes = tf.abstract_params(cfg)
    specs = partition.tree_pspecs(shapes, cfg=cfg, mesh_axes=info)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def opt_shardings(cfg: ModelConfig, mesh) -> Any:
    """NamedSharding tree for optimizer state (ZeRO-1 moments)."""
    info = meshlib.mesh_axes_info(mesh)
    shapes = tf.abstract_params(cfg)
    pspecs = partition.tree_pspecs(shapes, cfg=cfg, mesh_axes=info)
    ospecs = partition.opt_pspecs(pspecs, shapes, mesh_axes=info)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, oc: adamw.OptConfig, mesh, *, accum_steps: int = 1):
    """The jit-able train step (delegates to ``train.trainer``)."""
    from repro.train import trainer

    return trainer.make_train_step(cfg, oc, mesh, accum_steps=accum_steps)


def make_prefill_step(cfg: ModelConfig, mesh):
    """The jit-able prefill step for this config."""
    def prefill_step(params, batch):
        return tf.prefill(
            params, cfg, batch["tokens"], frontend=batch.get("frontend")
        )

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh):
    """The jit-able single-token decode step for this config."""
    def decode_step(params, batch):
        return tf.decode_step(
            params,
            cfg,
            batch["token"],
            batch["cache"],
            batch["pos"],
            frontend_src=batch.get("frontend_src"),
        )

    return decode_step


def resolve_dist(cfg: ModelConfig, mesh, *, serve_decode: bool = False) -> ModelConfig:
    """Pick the distribution policies for this mesh:
    - attention: head-sharded when head count divides the model axis,
      sequence-sharded otherwise (see attention._shard_qkv);
    - sequence-parallel residual (Megatron-SP) for train/prefill — not
      decode, where S == 1 (see partition.residual_spec)."""
    if mesh is None:
        return cfg
    info = meshlib.mesh_axes_info(mesh)
    ms = info["model_size"]
    if ms <= 1:
        return cfg
    policy = "head" if cfg.n_heads % ms == 0 else "seq"
    # Megatron-SP measured NEGATIVE on this XLA SPMD backend (collective
    # 7.83->8.32s on qwen2 train_4k: the partitioner keeps the AR and adds
    # reshards) — opt-in only.  EXPERIMENTS §Perf iteration 6.
    import os

    sp = os.environ.get("REPRO_SP", "0") == "1" and not serve_decode
    return cfg.with_(attn_shard=policy, sp=sp)


def make_step(cfg: ModelConfig, cell: ShapeCell, mesh, oc: adamw.OptConfig | None = None,
              *, accum_steps: int = 1):
    """Resolve distribution policies for the mesh and build the cell's step
    callable (train / prefill / decode)."""
    cfg = resolve_dist(cfg, mesh, serve_decode=cell.kind == "decode")
    if cell.kind == "train":
        return make_train_step(
            cfg, oc or adamw.OptConfig(), mesh, accum_steps=accum_steps
        )
    if cell.kind == "prefill":
        return make_prefill_step(cfg, mesh)
    return make_decode_step(cfg, mesh)
