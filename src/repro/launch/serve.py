"""Batched serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b-smoke \
      --requests 8 --prompt-len 48 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import Engine, Request


def main() -> None:
    """CLI driver: synthetic requests through the continuous-batching engine."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tf.init_params(key, cfg)
    rng = np.random.default_rng(args.seed)

    engine = Engine(cfg, params, batch_slots=args.slots, s_max=args.s_max)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: first tokens {r.out[:8]}")


if __name__ == "__main__":
    main()
