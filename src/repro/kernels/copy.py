"""Basic read/write kernels (paper §III-A).

The paper's primitive: stream data through the device at memcpy rate, with
templated access patterns (contiguous, ranged, index-set).  CUDA used 1-D
blocks with 4 elements per thread and automatic gridding; the TPU analogue
is a row-panel copy whose panel size is auto-planned against VMEM so each
grid step issues one large aligned DMA in and one out.

Ranged access keeps the paper's constant-memory trick via scalar prefetch:
the start offset rides in SMEM and feeds the load-side index map.

Index-set access lives in ``gather_scatter.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import LANES, cdiv, force_interpret, plan_copy_tiles


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _copy_range_kernel(s_ref, x_ref, o_ref):
    del s_ref  # consumed by the index maps
    o_ref[...] = x_ref[...]


def _as_2d(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """View x as (rows, cols) with a lane-friendly cols if possible."""
    if x.ndim >= 2:
        return x.reshape(-1, x.shape[-1]), x.shape
    L = x.shape[0]
    cols = 1
    for cand in (8192, 4096, 2048, 1024, 512, 256, LANES):
        if L % cand == 0:
            cols = cand
            break
    if cols == 1:
        raise ValueError(f"1-D length {L} has no lane-aligned factor")
    return x.reshape(L // cols, cols), x.shape


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def copy(
    x: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Contiguous device-to-device copy through VMEM panels."""
    x2, orig_shape = _as_2d(x)
    R, C = x2.shape
    plan = plan_copy_tiles(R, C, x.dtype)
    br = min(block_rows or plan.block_r, R)

    interpret = force_interpret() if interpret is None else interpret
    out = pl.pallas_call(
        _copy_kernel,
        grid=(cdiv(R, br),),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("size", "block_rows", "interpret"))
def copy_range(
    x: jax.Array,
    start: jax.Array,
    size: int,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Ranged read: rows [start, start+size) of a 2-D array.

    ``start`` is a *runtime* scalar (int32) delivered to the index map via
    scalar prefetch — the constant-memory analogue.  Row-granular: the
    kernel slides whole row panels; ``start`` need not be panel-aligned
    (the index map adds the row offset in block units after validating
    alignment at the chosen panel size of 1 row — i.e. panels are rows).
    """
    if x.ndim != 2:
        raise ValueError("copy_range expects 2-D (rows, cols)")
    R, C = x.shape
    br = block_rows or 1  # row-granular sliding window
    if size % br:
        raise ValueError(f"size {size} not divisible by block_rows {br}")

    interpret = force_interpret() if interpret is None else interpret
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(size // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i, s_ref: (i + s_ref[0], 0))],
        out_specs=pl.BlockSpec((br, C), lambda i, s_ref: (i, 0)),
    )
    start_blocks = (jnp.asarray(start, jnp.int32) // br)[None]
    return pl.pallas_call(
        _copy_range_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((size, C), x.dtype),
        interpret=interpret,
    )(start_blocks, x)
