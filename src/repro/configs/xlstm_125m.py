"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks (3:1 m:s ratio),
no positional embedding (recurrence carries position), GPT-NeoX vocab.
Sub-quadratic: runs the long_500k cell."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,  # blocks are self-contained (up/down proj inside)
    vocab=50304,
    head_dim=192,
    act="gelu",
    norm="layernorm",
    pos_embed="none",
    tie_embeddings=True,
    unit=("mlstm", "mlstm", "mlstm", "slstm"),
    subquadratic=True,
    source="arXiv:2405.04517 (unverified tier)",
)
