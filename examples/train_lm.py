"""End-to-end driver: train a ~117M-parameter dense LM for a few hundred
steps on synthetic data (deliverable (b) e2e example).

Where each training stage lowers through the plan engines:

* **attention** — `split_heads` (B,S,H·D)→(B,H,S,D) and its inverse are
  §3 rearrangement plans (`core/plan.py`): ONE V-deep batched-transpose
  kernel each, cached on (shape, dtype, perm) so steps after the first
  pay zero planning overhead.
* **data pipeline** — sequence packing selects rows by index table, the
  §4 index-set engine's blocked gather (`core/index_plan.py`).
* **on a mesh** (`--mesh production`) — parameter/batch sharding comes
  from `sharding/partition.py`; any resharding between layouts is what
  the §10 distributed planner (`core/dist_plan.py`) prices as
  local / all_to_all / replicate before falling back to XLA's choice.

  PYTHONPATH=src python examples/train_lm.py --steps 300 --batch 4 --seq 128
"""

from __future__ import annotations

import argparse
import sys

from repro.configs.base import ModelConfig

GPT_117M = ModelConfig(
    name="repro-gpt-117m",
    family="dense",
    n_layers=6,
    d_model=896,
    n_heads=14,
    n_kv_heads=14,
    d_ff=3584,
    vocab=50304,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    unit=("attn",),
    loss_chunk=128,
    attn_chunk=128,
    source="this repo (e2e example config)",
)


def main() -> None:
    # reuse the production launcher with the inline config
    from repro import configs as cfgmod
    from repro.launch import train as train_mod

    # register the example config so --arch resolves it
    cfgmod._MODULES  # noqa: B018 — ensure import
    orig_get = cfgmod.get_config

    def get_config(name):
        if name == "repro-gpt-117m":
            return GPT_117M
        return orig_get(name)

    cfgmod.get_config = get_config
    train_mod.configs.get_config = get_config

    sys.argv = [sys.argv[0], "--arch", "repro-gpt-117m"] + sys.argv[1:]
    train_mod.main()


if __name__ == "__main__":
    main()
