"""Phi-3-mini 3.8B [arXiv:2404.14219] — dense decoder, RoPE + SwiGLU.
GQA kv=32 == MHA at this size (per the assigned spec)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    head_dim=96,
    qkv_bias=False,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=False,
    unit=("attn",),
    source="arXiv:2404.14219 (unverified tier)",
)
