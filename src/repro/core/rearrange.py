"""Public rearrangement API (the paper's library surface, §III).

Every entry point accepts either numpy-convention permutations or the
paper's fastest-first ``order`` vectors, and dispatches through
``repro.kernels.ops`` (Pallas on TPU, fused-XLA oracle elsewhere).  Each
permute-shaped call routes through the plan engine (`core/plan.py`):
collapse adjacent axes -> route to the cheapest kernel -> cached plan.

Model-facing fused helpers (`split_qkv`, `split_heads`, `space_to_depth`,
`rope_halves`, ...) make the kernels first-class citizens of the training
framework — see DESIGN.md §4 for the mapping.  The reshape halves of each
helper fold into the plan's canonical shape (metadata-only merges of a
contiguous array), so every helper lowers to a SINGLE kernel invocation —
never a materialized reshape intermediate.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.core.plan import plan_rearrange
from repro.kernels import ops

Array = jax.Array

# ---------------------------------------------------------------------------
# §III-B permute / reorder
# ---------------------------------------------------------------------------


def permute(x: Array, perm: Sequence[int]) -> Array:
    """out = transpose(x, perm), numpy convention."""
    return ops.permute(x, tuple(perm))


def permute_order(x: Array, order: Sequence[int]) -> Array:
    """Paper convention: ``order`` lists input dims fastest-first for the
    output (row-major linearized storage, paper §III-B)."""
    return ops.permute(x, layout.paper_order_to_perm(order))


def reorder(
    x: Array,
    out_order: Sequence[int],
    *,
    base: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
) -> Array:
    """Generic N->M reorder, paper convention.  ``out_order`` lists the
    input dims (paper numbering, fastest-first) appearing in the output;
    dims not listed are fixed at ``base`` with window size 1."""
    nd = x.ndim
    # paper dim k <-> numpy axis nd-1-k
    kept_np = [nd - 1 - k for k in out_order]
    perm = tuple(reversed(kept_np))  # slowest-first for numpy
    return ops.reorder_nm(x, perm, base=base, sizes=sizes)


def transpose(x: Array) -> Array:
    """2-D transpose (paper's [1 0] permute)."""
    if x.ndim != 2:
        raise ValueError(f"transpose wants 2-D, got {x.shape}")
    return ops.permute(x, (1, 0))


# ---------------------------------------------------------------------------
# affine ops (DESIGN.md §14): requests the analytic planner recognizes
# beyond plain permutations — each ONE kernel pass, no index tables
# ---------------------------------------------------------------------------


def bit_reversal(x: Array, *, axis: int = 0) -> Array:
    """Bit-reversal reorder along ``axis`` (FFT layouts); the axis length
    must be a power of two."""
    return ops.bit_reversal(x, axis=axis)


def strided_gather(x: Array, stride: int, *, phase: int = 0, axis: int = 0) -> Array:
    """Strided window gather ``x[..., phase::stride, ...]`` along ``axis``."""
    return ops.strided_gather(x, stride, phase=phase, axis=axis)


def diagonal_reorder(x: Array) -> Array:
    """Skewed-diagonal reorder of the trailing plane:
    ``out[..., i, j] = x[..., i, (i + j) % C]``."""
    return ops.diagonal_reorder(x)


def shuffle(x: Array, seed: int = 0) -> Array:
    """Table-free seeded bijective row shuffle (epoch shuffling,
    ROADMAP item 3): same seed, same permutation, no index table in HBM."""
    return ops.shuffle(x, seed)


# ---------------------------------------------------------------------------
# §III-C interlace / de-interlace (axis-generalized)
# ---------------------------------------------------------------------------


def interlace(arrays: Sequence[Array]) -> Array:
    """n same-shape arrays -> one array with the last axis interleaved:
    out[..., j*n + k] = arrays[k][..., j].  N-D flattening happens inside
    the op (metadata-only), so this is a single kernel pass."""
    return ops.interlace(list(arrays))


def deinterlace(x: Array, n: int) -> list[Array]:
    """Inverse of :func:`interlace` along the last axis (single kernel)."""
    return ops.deinterlace(x, n)


# ---------------------------------------------------------------------------
# framework-facing fused helpers (DESIGN.md §4)
# ---------------------------------------------------------------------------


def split_qkv(
    qkv: Array, n_q_heads: int, n_kv_heads: int, head_dim: int
) -> tuple[Array, Array, Array]:
    """De-interlace a fused QKV projection (..., (Hq+2*Hkv)*D) into
    q (..., Hq*D), k (..., Hkv*D), v (..., Hkv*D).  The fused layout is
    block-concatenated (the common convention), so this is a ranged read."""
    dq = n_q_heads * head_dim
    dkv = n_kv_heads * head_dim
    q = qkv[..., :dq]
    k = qkv[..., dq : dq + dkv]
    v = qkv[..., dq + dkv :]
    return q, k, v


def split_heads(x: Array, n_heads: int) -> Array:
    """(B, S, H*D) -> (B, H, S, D): the attention head permute.

    The leading reshape is metadata-only; the (0, 2, 1, 3) permute is the
    adjacent-swap family, so the planner routes it to ONE batched 2-D
    transpose kernel with D-deep vector elements (plan mode 'transpose')."""
    b, s, hd = x.shape
    d = hd // n_heads
    return ops.permute(x.reshape(b, s, n_heads, d), (0, 2, 1, 3))


def merge_heads(x: Array) -> Array:
    """(B, H, S, D) -> (B, S, H*D): inverse of :func:`split_heads`, the same
    single batched-transpose kernel with the trailing reshape folded away."""
    b, h, s, d = x.shape
    return ops.permute(x, (0, 2, 1, 3)).reshape(b, s, h * d)


def rope_halves(x: Array) -> tuple[Array, Array]:
    """Split the head dim into (first, second) halves for rotary embedding
    (the planar convention; the interleaved convention would be
    ``deinterlace(x, 2)`` — both are §III-C patterns)."""
    d = x.shape[-1]
    return x[..., : d // 2], x[..., d // 2 :]


def space_to_depth(img: Array, patch: int) -> Array:
    """(B, H, W, C) -> (B, H/p, W/p, p*p*C): the ViT patchify reorder —
    an N->M reorder in the paper's taxonomy (§III-B).

    The rank-6 permute collapses to canonical (B*H/p, p, W/p, p*C) with
    perm (0, 2, 1, 3) — again the swap family, so the whole patchify is a
    single batched-transpose kernel despite the two framing reshapes."""
    b, h, w, c = img.shape
    x = img.reshape(b, h // patch, patch, w // patch, patch, c)
    x = ops.permute(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, h // patch, w // patch, patch * patch * c)


def kv_cache_to_decode_layout(k: Array) -> Array:
    """(B, S, H, D) prefill layout -> (B, H, S, D) decode layout.
    Decode reads one (H, D) slab per new token but attends over S; keeping
    S minor-adjacent to D makes the attention matmul layout-native."""
    return ops.permute(k, (0, 2, 1, 3))


def plan(x: Array, perm: Sequence[int], *, grid_order: str = "out"):
    """Expose the (cached) planner for inspection/benchmarks."""
    return plan_rearrange(x.shape, x.dtype, tuple(perm), grid_order=grid_order)
