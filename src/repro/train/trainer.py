"""Training step factory: grad-accumulation microbatching, fp32 grad
accumulators, AdamW update, metrics.

Gradient accumulation is the memory-term lever (EXPERIMENTS.md §Perf):
activation temp scales with the microbatch, while the collective term is
unchanged (grads are reduced once per step, after accumulation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tf
from repro.optim import adamw
from repro.sharding import partition
from repro.utils.scanutil import maybe_scan


def make_train_step(cfg, oc: adamw.OptConfig, mesh, *, accum_steps: int = 1):
    """Build the jittable train step: value_and_grad over the (blockwise
    when ``cfg.blockwise``) chunked loss, ``accum_steps`` microbatches
    summed into fp32 accumulators, then one AdamW update.

    The returned ``train_step(params, opt_state, batch) -> (params,
    opt_state, metrics)`` raises ``ValueError`` if the global batch is not
    divisible by ``accum_steps``; with a ``mesh`` the loss runs under the
    sharded ``residual_spec`` constraint path.
    """
    bspec = partition.residual_spec(cfg) if mesh is not None else None

    def lossf(p, batch):
        return tf.loss_fn(
            p,
            cfg,
            batch["tokens"],
            batch["labels"],
            frontend=batch.get("frontend"),
            batch_spec=bspec,
        )

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(lossf)(params, batch)
        else:
            bsz = batch["tokens"].shape[0]
            if bsz % accum_steps:
                raise ValueError(
                    f"global batch {bsz} is not divisible by "
                    f"accum_steps={accum_steps}; pick accum_steps that "
                    f"divides the batch (microbatch = batch / accum_steps)"
                )
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                batch,
            )

            def body(carry, mb):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(lossf)(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads
                )
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = maybe_scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
        params2, opt2, metrics = adamw.update(params, grads, opt_state, oc)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


def make_eval_step(cfg, mesh):
    """Build the jittable eval step: ``eval_step(params, batch) -> loss``
    over the same chunked loss the train step differentiates."""
    bspec = partition.residual_spec(cfg) if mesh is not None else None

    def eval_step(params, batch):
        return tf.loss_fn(
            params,
            cfg,
            batch["tokens"],
            batch["labels"],
            frontend=batch.get("frontend"),
            batch_spec=bspec,
        )

    return eval_step
