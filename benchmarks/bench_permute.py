"""Paper Table 1: 3D permute, all 6 orders, 128x256x512 fp32."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import layout
from repro.kernels import ops

ORDERS = [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]]


def run() -> list[str]:
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((128, 256, 512)), jnp.float32
    )
    nbytes = 2 * x.size * 4
    out = []
    for order in ORDERS:
        perm = layout.paper_order_to_perm(order)
        fn = jax.jit(lambda a, p=perm: ops.permute(a, p))
        t = time_fn(fn, x)
        mode = layout.canonicalize(x.shape, perm).mode
        out.append(row(f"permute3d_{''.join(map(str, order))}", t, nbytes, f"[{mode}]"))
    return out
