"""Batched serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b-smoke \
      --requests 8 --prompt-len 48 --max-new 16 --chunk 32

Set ``REPRO_SERVE_FLAGS=1`` (or pass ``--serve-flags``) to apply the XLA
inference preset (`repro.launch.xla_flags`) before the backend starts.
"""

from __future__ import annotations

import argparse
import time

from repro.launch import xla_flags


def main() -> None:
    """CLI driver: synthetic requests through the continuous-batching engine."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--prefill-mode", choices=["ragged", "bucket"], default=None,
        help="admission route (default: ragged when the arch supports it)",
    )
    ap.add_argument(
        "--chunk", type=int, default=None,
        help="tokens prefilled per engine step (ragged mode); "
             "default: whole prompt at admit",
    )
    ap.add_argument(
        "--serve-flags", action="store_true",
        help="apply the REPRO_SERVE_FLAGS XLA inference preset",
    )
    args = ap.parse_args()

    merged = xla_flags.apply_serve_flags(force=args.serve_flags)
    if args.serve_flags and merged is None:
        print("serve-flags: no TPU runtime detected, preset skipped")

    # import after the flag preset: XLA reads XLA_FLAGS at backend init
    import jax
    import numpy as np

    from repro import configs
    from repro.models import transformer as tf
    from repro.serve.engine import Engine, Request

    cfg = configs.get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tf.init_params(key, cfg)
    rng = np.random.default_rng(args.seed)

    engine = Engine(
        cfg, params, batch_slots=args.slots, s_max=args.s_max,
        prefill_mode=args.prefill_mode, chunk=args.chunk,
    )
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s) [mode={engine.mode} chunk={engine.chunk}]")
    for r in done[:3]:
        print(f"  req {r.rid}: first tokens {r.out[:8]}")


if __name__ == "__main__":
    main()
