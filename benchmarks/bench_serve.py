"""Beyond-paper: the serving engine's measured hot paths (DESIGN §12).

Three comparisons at equal semantics:

* **split-KV vs one-shot decode attention** — `kernels.flash.flash_decode`
  (two-stage mid-softmax reduce, per-slot lengths) against the rectangular
  one-shot flash kernel at sq=1 and the jnp oracle.  Off-TPU both kernels
  run through the Pallas interpreter, where runtime tracks grid steps —
  the same proxy the other suites use; the split-KV grid streams K/V once
  per *KV* head instead of once per query head, so the GQA group factor
  shows up directly.  Both kernel rows use the same algorithmic byte
  count, so the GB/s ratio in ``tools/check_bench.py`` is a pure time
  ratio (floor: split-KV >= 1.0x one-shot).
* **ragged vs bucket admission** — one packed `prefill_ragged` wave
  against the seed's per-request left-padded bucket prefills for the same
  prompts.
* **the multi-tenant trace** — a seeded synthetic trace (mixed prompt
  lengths, Poisson arrivals in engine steps) through the continuous
  batching engine, ragged+chunked vs bucket mode, reporting tokens/s and
  p50/p99 per-token latency (inter-token gap a client of a slot
  observes).  Rows land in ``BENCH_serve.json`` (see benchmarks/run.py).
"""

from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, smoke, time_fn
from repro import configs
from repro.kernels import flash
from repro.models import attention
from repro.models import transformer as tf
from repro.serve.engine import Engine, Request


def _decode_rows(out: list[str]) -> None:
    """Kernel-level decode comparison: oracle vs one-shot vs split-KV."""
    b, hq, hkv, s, d = (2, 8, 2, 256, 32) if smoke() else (4, 16, 4, 1024, 64)
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, 1, d), jnp.float32)
    k = jax.random.normal(kk, (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(kv_, (b, hkv, s, d), jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    # one algorithmic byte count for every row: K/V streamed once + q/o
    nbytes = 4 * (2 * b * hkv * s * d + 2 * b * hq * d)
    plan = flash.plan_flash_decode(b, hq, hkv, s, d, jnp.float32)
    out.append(f"# decode shapes b={b} hq={hq} hkv={hkv} s={s} d={d}")
    out.append(f"# split-KV plan: {plan.describe()}")

    oracle = jax.jit(
        lambda a, c, w: attention.decode_attention(a, c, w, length=s, engine="oneshot")
    )
    t_or = time_fn(oracle, q, k, v)
    out.append(
        row("decode_oneshot_oracle", t_or, nbytes,
            plan_mode="jnp_masked", measured="xla_oracle")
    )

    interp = jax.default_backend() != "tpu"
    oneshot = jax.jit(
        lambda a, c, w: flash.flash_attention(a, c, w, causal=False, interpret=interp)
    )
    t_one = time_fn(oneshot, q, k, v)
    out.append(
        row("decode_oneshot_interp", t_one, nbytes, "[seed one-shot kernel, sq=1]",
            plan_mode="oneshot", measured="pallas")
    )

    splitkv = jax.jit(
        lambda a, c, w: flash.flash_decode(a, c, w, lengths=lens, interpret=interp)
    )
    t_sp = time_fn(splitkv, q, k, v)
    out.append(
        row("decode_splitkv_interp", t_sp, nbytes,
            f"[{plan.num_splits} splits x bk={plan.block_k}, "
            f"{t_one/t_sp:.2f}x vs one-shot]",
            plan_mode="splitkv", measured="pallas",
            num_splits=plan.num_splits, block_k=plan.block_k,
            improvement_vs_oneshot=round(t_one / t_sp, 3),
            plan_bytes=flash.decode_dma_bytes(
                b, hq, hkv, s, d, 4,
                num_splits=plan.num_splits, block_k=plan.block_k,
            ))
    )


def _prompts(rng: np.random.Generator, cfg, n: int) -> list[np.ndarray]:
    """Mixed-length synthetic prompts (the multi-tenant part of the trace)."""
    # hi keeps bucket-mode viable: round_up(hi, bucket) + max_new < s_max,
    # so both engine modes emit every token and the traces stay comparable
    lo, hi = (4, 36) if smoke() else (8, 90)
    return [
        rng.integers(0, cfg.vocab, int(rng.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


def _kv_step_bytes(cfg, slots: int, s_max: int) -> int:
    """Approximate per-decode-step KV traffic: every attention layer
    streams each slot's full ring once."""
    n_attn = sum(count * len(unit) for unit, count in cfg.decoder_plan())
    item = jnp.dtype(cfg.np_dtype).itemsize
    return n_attn * 2 * slots * cfg.n_kv_heads * s_max * cfg.head_dim * item


def _run_trace(engine: Engine, reqs: list[Request], arrive: list[int]):
    """Drive one trace: admit at each request's arrival step, step the
    engine, collect per-iteration wall times and token counts."""
    pending: deque[Request] = deque()
    lat: list[float] = []
    nxt = 0
    step = 0
    t0 = time.perf_counter()
    while nxt < len(reqs) or pending or any(r is not None for r in engine.live):
        it0 = time.perf_counter()
        while nxt < len(reqs) and arrive[nxt] <= step:
            pending.append(reqs[nxt])
            nxt += 1
        before = sum(len(r.out) for r in reqs)
        n_free = len(engine.free_slots())
        if pending and n_free:
            wave = [pending.popleft() for _ in range(min(n_free, len(pending)))]
            engine.admit_batch(wave)
        engine.step()
        new = sum(len(r.out) for r in reqs) - before
        lat.extend([time.perf_counter() - it0] * new)
        step += 1
    total = time.perf_counter() - t0
    return total, step, lat


def _trace_rows(out: list[str]) -> None:
    """Engine-level trace: ragged+chunked vs bucket continuous batching."""
    cfg = configs.get_config("qwen2-7b-smoke")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    n_req, slots, s_max, chunk = (6, 3, 64, 16) if smoke() else (16, 4, 128, 32)
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, cfg, n_req)
    # Poisson arrivals: exponential inter-arrival gaps, in engine steps
    gaps = rng.exponential(scale=2.0, size=n_req)
    arrive = np.floor(np.cumsum(gaps)).astype(int).tolist()
    max_new = 4 if smoke() else 12
    out.append(
        f"# trace: {n_req} reqs, prompts {min(map(len, prompts))}.."
        f"{max(map(len, prompts))} toks, arrivals {arrive}, max_new={max_new}"
    )
    step_bytes = _kv_step_bytes(cfg, slots, s_max)

    for name, mode, ch in (
        ("serve_trace_ragged_chunked", "ragged", chunk),
        ("serve_trace_ragged", "ragged", None),
        ("serve_trace_bucket", "bucket", None),
    ):
        engine = Engine(
            cfg, params, batch_slots=slots, s_max=s_max,
            prompt_bucket=16 if smoke() else 32, prefill_mode=mode, chunk=ch,
        )

        def fresh():
            return [
                Request(rid=i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)
            ]

        _run_trace(engine, fresh(), arrive)  # warm the jit caches
        engine.reset()
        reqs = fresh()
        total, steps, lat = _run_trace(engine, reqs, arrive)
        toks = sum(len(r.out) for r in reqs)
        lat_ms = np.sort(np.asarray(lat)) * 1e3
        p50 = float(np.percentile(lat_ms, 50))
        p99 = float(np.percentile(lat_ms, 99))
        out.append(
            row(name, total, steps * step_bytes,
                f"[{toks} toks, {toks/total:.1f} tok/s, "
                f"p50 {p50:.1f}ms p99 {p99:.1f}ms, {steps} steps]",
                plan_mode=mode, measured="engine", tokens=toks,
                engine_steps=steps, chunk=ch if ch else 0,
                tokens_per_s=round(toks / total, 2),
                p50_ms=round(p50, 3), p99_ms=round(p99, 3))
        )


def _admission_rows(out: list[str]) -> None:
    """One packed ragged admission wave vs per-request bucket prefills."""
    cfg = configs.get_config("qwen2-7b-smoke")
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    n, s_max = (3, 64) if smoke() else (4, 128)
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, cfg, n)
    total_toks = sum(len(p) for p in prompts)
    n_attn = sum(count * len(unit) for unit, count in cfg.decoder_plan())
    item = jnp.dtype(cfg.np_dtype).itemsize
    nbytes = n_attn * 2 * cfg.n_kv_heads * total_toks * cfg.head_dim * item

    times = {}
    for name, mode in (
        ("prefill_ragged_wave", "ragged"),
        ("prefill_bucket_wave", "bucket"),
    ):
        engine = Engine(
            cfg, params, batch_slots=n, s_max=s_max, prompt_bucket=16,
            prefill_mode=mode,
        )

        def wave(e=engine):
            e.reset()
            e.admit_batch(
                [Request(rid=i, prompt=p, max_new=2) for i, p in enumerate(prompts)]
            )
            jax.block_until_ready(e.cache)

        wave()  # compile
        t = time_fn(wave)
        times[name] = t
        note = ""
        if name == "prefill_bucket_wave":
            note = f"[{t/times['prefill_ragged_wave']:.2f}x slower than ragged]"
        out.append(
            row(name, t, nbytes, note, plan_mode=mode, measured="engine",
                prompts=n, prompt_tokens=total_toks)
        )


def run():
    """Suite entry point (benchmarks.run)."""
    out: list[str] = []
    _decode_rows(out)
    _admission_rows(out)
    _trace_rows(out)
    return out
