"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b-smoke \
      --steps 50 --batch 8 --seq 128 --checkpoint-dir runs/ckpt

Runs on whatever devices exist (host mesh); on a TPU pod slice the same
driver runs the production mesh with --mesh production.  Supports
checkpoint/restart (auto-resumes from the latest step), grad
accumulation, and straggler flagging.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, Prefetcher, make_source
from repro.launch import mesh as meshlib
from repro.launch import specs
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train import elastic
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import make_train_step


def main() -> None:
    """CLI driver: train on synthetic data with checkpointing + elasticity."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m-smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="host", choices=["host", "production", "production-multipod"])
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.mesh == "host":
        mesh = meshlib.make_host_mesh()
    else:
        mesh = meshlib.make_production_mesh(
            multi_pod=args.mesh == "production-multipod"
        )
    cfg = specs.resolve_dist(cfg, mesh)
    oc = adamw.OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 20))

    key = jax.random.PRNGKey(args.seed)
    with meshlib.set_mesh_compat(mesh):
        params = init_sharded(cfg, key, mesh)
        opt_state = adamw.init(params)
        step_fn = jax.jit(
            make_train_step(cfg, oc, mesh, accum_steps=args.accum),
            donate_argnums=(0, 1),
        )

        dc = DataConfig(batch=args.batch, seq=args.seq, vocab=cfg.vocab, seed=args.seed)
        source = make_source(dc)

        start = 0
        ckpt = None
        if args.checkpoint_dir:
            ckpt = Checkpointer(args.checkpoint_dir)
            latest = ckpt.latest_step()
            if latest is not None:
                skel = {"params": params, "opt": opt_state}
                restored = ckpt.restore(latest, jax.tree.map(np.asarray, skel))
                params = jax.tree.map(jnp.asarray, restored["params"])
                opt_state = jax.tree.map(jnp.asarray, restored["opt"])
                start = latest
                print(f"resumed from step {latest}")

        prefetch = Prefetcher(source, start_step=start)
        timer = elastic.StepTimer()
        t_start = time.time()
        for _ in range(start, args.steps):
            step_i, batch = prefetch.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.encoder_layers or cfg.n_frontend_tokens:
                batch["frontend"] = jnp.zeros(
                    (args.batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
                )
            timer.start()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            straggler = timer.stop()
            if (step_i + 1) % args.log_every == 0 or step_i == start:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                print(
                    f"step {step_i+1:5d} loss {loss:8.4f} gnorm {gn:7.3f}"
                    + (" [straggler]" if straggler else ""),
                    flush=True,
                )
            if ckpt and (step_i + 1) % args.checkpoint_every == 0:
                ckpt.save(step_i + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state})
            ckpt.wait()
        prefetch.close()
        dt = time.time() - t_start
        n = args.steps - start
        print(f"done: {n} steps in {dt:.1f}s ({dt/max(n,1)*1e3:.0f} ms/step)")


def init_sharded(cfg, key, mesh):
    """Initialize params directly into their mesh shardings (no host copy)."""
    pshard = specs.param_shardings(cfg, mesh)
    init = jax.jit(
        lambda k: tf.init_params(k, cfg), out_shardings=pshard
    )
    return init(key)


if __name__ == "__main__":
    main()
