"""Benchmark harness utilities.

The paper's metric is achieved bandwidth as a fraction of device-to-device
``memcpy`` (77 GB/s on the C1060).  On this CPU container we reproduce the
*methodology*: measure each op's achieved GB/s with the same timing loop
used for the host memcpy baseline, and report the fraction.  TPU roofline
numbers for the same ops come from the dry-run analysis (bench_roofline).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

# --smoke (benchmarks.run) flips this: tiny shapes, reduced timing loops,
# deterministic seeds — the harness itself exercised on every PR (and by
# tools/check_bench.py) instead of only on bare-metal runs.  The env
# mirror propagates the flag into the bench_dist subprocess.
SMOKE = False


def smoke() -> bool:
    """True when the harness runs in --smoke mode (tiny deterministic
    shapes; see benchmarks/run.py and tools/check_bench.py)."""
    return SMOKE or os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


def time_fn(fn, *args, warmup: int | None = None, iters: int | None = None) -> float:
    """Best-of-iters seconds for fn(*args) with device sync."""
    if warmup is None:
        warmup = 1 if smoke() else 2
    if iters is None:
        iters = 2 if smoke() else 5
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        best = min(best, time.perf_counter() - t0)
    return best


_MEMCPY_CACHE: dict[int, float] = {}


def memcpy_gbps(nbytes: int | None = None) -> float:
    """Host memcpy bandwidth — the baseline every kernel is normalized to
    (the paper's cudaMemcpy d2d reference)."""
    if nbytes is None:
        nbytes = 1 << 24 if smoke() else 1 << 28
    if nbytes not in _MEMCPY_CACHE:
        src = np.empty(nbytes, np.uint8)
        dst = np.empty_like(src)
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            np.copyto(dst, src)
            best = min(best, time.perf_counter() - t0)
        _MEMCPY_CACHE[nbytes] = 2 * nbytes / best / 1e9  # read + write
    return _MEMCPY_CACHE[nbytes]


# machine-readable record stream: every row() call also appends a dict here;
# benchmarks.run dumps the accumulated records to BENCH_rearrange.json so the
# perf trajectory is tracked across PRs.
RECORDS: list[dict] = []


def row(name: str, seconds: float, bytes_moved: int, note: str = "", **fields) -> str:
    gbps = bytes_moved / seconds / 1e9
    frac = gbps / memcpy_gbps()
    RECORDS.append(
        {
            "op": name,
            "us_per_call": round(seconds * 1e6, 1),
            "gbps": round(gbps, 3),
            "frac_memcpy": round(frac, 4),
            **fields,
        }
    )
    return f"{name},{seconds*1e6:.1f},{gbps:.2f} GB/s ({frac*100:.0f}% of memcpy){(' ' + note) if note else ''}"
