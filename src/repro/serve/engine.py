"""Batched serving engine: prefill + decode with slot-based continuous
batching over the ring-buffer KV caches.

The engine owns B fixed slots.  Requests are prefilled (building each
layer's decode-layout cache via the library's KV permute — DESIGN.md §4)
and written into a free slot; every engine step decodes one token for
all live slots; finished slots are immediately reusable.  Static shapes
throughout: one compiled prefill per prompt bucket, one compiled decode.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf

Array = jax.Array


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    out: list = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, *, batch_slots: int = 4, s_max: int = 256,
                 prompt_bucket: int = 64):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.bucket = prompt_bucket
        self.cache = tf.init_cache(cfg, batch_slots, s_max)
        self.pos = np.zeros(batch_slots, np.int32)  # per-slot next position
        self.live: list[Request | None] = [None] * batch_slots
        self.frontend = None
        self._decode = jax.jit(
            lambda p, tok, cache, pos: tf.decode_step(p, cfg, tok, cache, pos)
        )
        self._prefill = jax.jit(
            lambda p, toks: tf.prefill(p, cfg, toks)
        )

    # -- admission -----------------------------------------------------------

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (single-row prefill)."""
        slot = self._free_slot()
        if slot is None:
            return False
        s = len(req.prompt)
        pad = -(-s // self.bucket) * self.bucket
        toks = np.zeros((1, pad), np.int32)
        toks[0, pad - s :] = req.prompt  # left-pad into the bucket
        logits, cache1 = self._prefill(self.params, jnp.asarray(toks))
        # copy the single-row cache into the slot (KV rows land at [0, pad))
        self.cache = _write_slot(self.cache, cache1, slot, self.s_max)
        self.pos[slot] = pad
        req.out.append(int(np.argmax(np.asarray(logits)[0])))
        self.live[slot] = req
        return True

    # -- stepping ------------------------------------------------------------

    def step(self) -> int:
        """Decode one token for every live slot; returns #live."""
        live_ix = [i for i, r in enumerate(self.live) if r is not None]
        if not live_ix:
            return 0
        toks = np.zeros((self.b,), np.int32)
        for i in live_ix:
            toks[i] = self.live[i].out[-1]
        # engine-level position: slots decode at their own pos; the compiled
        # step takes a single pos scalar, so we step the max and mask via
        # per-slot cache lengths (ring caches make stale rows harmless).
        pos = int(self.pos[live_ix].max() if hasattr(self.pos, "max") else 0)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, jnp.int32(pos)
        )
        lg = np.asarray(logits)
        for i in live_ix:
            r = self.live[i]
            r.out.append(int(np.argmax(lg[i])))
            self.pos[i] += 1
            if len(r.out) >= r.max_new:
                r.done = True
                self.live[i] = None
        return len(live_ix)

    def run(self, requests: list[Request]) -> list[Request]:
        pending = list(requests)
        done: list[Request] = []
        while pending or any(r is not None for r in self.live):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step()
            done = [r for r in requests if r.done]
        return done


def _write_slot(cache, cache1, slot: int, s_max: int):
    """Copy a 1-row prefill cache into slot ``slot`` of the engine cache,
    padding KV sequence dims up to s_max."""

    def merge(dst, src):
        if isinstance(dst, dict):
            return {k: merge(dst[k], src[k]) for k in dst}
        if isinstance(dst, list):
            return [merge(a, b) for a, b in zip(dst, src)]
        # dst (count, B, ...), src (count, 1, ...)
        if dst.ndim >= 3 and src.shape[1] == 1:
            row = src[:, 0]
            target = dst.shape[:1] + dst.shape[2:]  # slot slice shape
            if row.shape != target:
                # KV ring buffers: prefill wrote fewer sequence rows; pad
                # the seq axis (-2) up to the engine's s_max
                pad = [(0, 0)] * row.ndim
                pad[-2] = (0, target[-2] - row.shape[-2])
                row = jnp.pad(row, pad)
            return dst.at[:, slot].set(row.astype(dst.dtype))
        return dst

    return merge(cache, cache1)
