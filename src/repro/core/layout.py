"""Layout algebra: the paper's order-vector convention, coalescing, and
canonicalization of N-D reorders onto the batched-2-D movement plane.

Paper convention ("order" vectors)
----------------------------------
The paper describes storage with an ``order`` vector listing dimension ids
*fastest-changing first*.  numpy/JAX are row-major: the **last** axis is
fastest.  With paper dim ``k`` <-> numpy axis ``N-1-k``:

    perm[j] = N - 1 - order[N - 1 - j]

maps a paper order vector (for the output, fastest-first, entries naming
*input* dims) onto a numpy transpose permutation ``out axis j <- in axis
perm[j]``.  Identity order [0, 1, .., N-1] maps to the identity perm.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


def paper_order_to_perm(order: Sequence[int]) -> tuple[int, ...]:
    """Paper fastest-first order vector -> numpy transpose permutation."""
    n = len(order)
    if sorted(order) != list(range(n)):
        raise ValueError(f"order {order} is not a permutation of 0..{n-1}")
    return tuple(n - 1 - order[n - 1 - j] for j in range(n))


def perm_to_paper_order(perm: Sequence[int]) -> tuple[int, ...]:
    """Inverse of :func:`paper_order_to_perm` (the mapping is an involution
    on the index transform, not on the vector itself)."""
    n = len(perm)
    return tuple(n - 1 - perm[n - 1 - k] for k in range(n))


def invert_perm(perm: Sequence[int]) -> tuple[int, ...]:
    """Inverse permutation: ``transpose(transpose(x, perm), invert_perm(perm))
    == x``."""
    inv = [0] * len(perm)
    for j, p in enumerate(perm):
        inv[p] = j
    return tuple(inv)


def compose_perm(p: Sequence[int], q: Sequence[int]) -> tuple[int, ...]:
    """Permutation applying q then p: transpose(transpose(x, q), p)."""
    return tuple(q[pj] for pj in p)


def coalesce(
    shape: Sequence[int], perm: Sequence[int]
) -> tuple[tuple[int, ...], tuple[int, ...], list[list[int]]]:
    """Merge input-axis runs that stay adjacent (in order) in the output.

    Returns (new_shape, new_perm, groups) where ``groups[g]`` lists the
    original input axes folded into merged axis ``g``.  Size-1 axes are
    absorbed.  This is standard transpose coalescing; the paper gets the
    same effect implicitly by choosing movement planes.
    """
    nd = len(shape)
    keep = [ax for ax in range(nd) if shape[ax] != 1]
    if not keep:
        return (1,) * min(1, nd), (0,) if nd else (), [list(range(nd))]
    perm_k = [p for p in perm if shape[p] != 1]

    # group consecutive kept input axes that appear consecutively in output
    groups: list[list[int]] = []
    pos_in_perm = {ax: i for i, ax in enumerate(perm_k)}
    for ax in keep:
        if (
            groups
            and groups[-1][-1] == ax - 1
            and pos_in_perm[ax] == pos_in_perm[groups[-1][-1]] + 1
        ):
            groups[-1].append(ax)
        else:
            groups.append([ax])
    group_of = {}
    for g, axes in enumerate(groups):
        for ax in axes:
            group_of[ax] = g
    new_shape = tuple(math.prod(shape[ax] for ax in axes) for axes in groups)
    seen: set[int] = set()
    new_perm = []
    for ax in perm_k:
        g = group_of[ax]
        if g not in seen:
            seen.add(g)
            new_perm.append(g)
    # fold dropped size-1 axes into the nearest group for bookkeeping
    for ax in range(nd):
        if shape[ax] == 1:
            tgt = min(group_of.values(), default=0)
            groups[tgt].append(ax)
    return new_shape, tuple(new_perm), groups


def swap_factors(
    shape: Sequence[int], perm: Sequence[int]
) -> tuple[int, int, int, int] | None:
    """Factor a (coalesced) permutation as a batched 2-D transpose.

    A permutation is in the *batched-transpose family* iff it is a single
    adjacent-pair swap: ``(0..b-1, b+1, b, b+2..n-1)``.  Every such reorder
    is exactly ``(B, R, C, V) -> (B, C, R, V)`` movement, where B collapses
    the identity prefix, V collapses the identity suffix (the contiguous
    vector payload each (r, c) element carries), and (R, C) is the movement
    plane — the paper's batched 2-D transpose with both sides coalesced.

    Returns ``(B, R, C, V)`` sizes, or None when the perm is not a single
    adjacent swap.  After :func:`coalesce` the prefix and suffix are each at
    most one axis, so the canonical family is exactly
    ``{(1,0), (0,2,1), (1,0,2), (0,2,1,3)}``.
    """
    n = len(perm)
    moved = [i for i in range(n) if perm[i] != i]
    if len(moved) != 2:
        return None
    i, j = moved
    if j != i + 1 or perm[i] != j or perm[j] != i:
        return None
    batch = math.prod(shape[:i]) if i else 1
    vec = math.prod(shape[j + 1 :]) if j + 1 < n else 1
    return batch, shape[i], shape[j], vec


@dataclass(frozen=True)
class Canonical:
    """A reorder reduced to its movement plane (paper §III-B).

    mode:
      'identity'   no movement beyond a streaming copy
      'transpose'  fastest axis changes: batched 2-D transpose plane
      'copy'       fastest axis preserved: blocked row gather
    rows/cols: the two blocked axes (input indices, post-coalescing)
    """

    mode: str
    shape: tuple[int, ...]
    perm: tuple[int, ...]
    rows_axis: int | None
    cols_axis: int | None


def canonicalize(shape: Sequence[int], perm: Sequence[int]) -> Canonical:
    """Coalesce adjacent axes and classify the residual movement — the
    'collapse' half of the plan engine (DESIGN.md §3 step 1+2)."""
    cshape, cperm, _ = coalesce(shape, perm)
    n = len(cshape)
    if n <= 1 or cperm == tuple(range(n)):
        return Canonical("identity", cshape, cperm, None, None)
    c_in = n - 1
    if cperm[-1] == c_in:
        r_in = cperm[-2] if n >= 2 else None
        return Canonical("copy", cshape, cperm, r_in, c_in)
    return Canonical("transpose", cshape, cperm, cperm[-1], c_in)


# ---------------------------------------------------------------------------
# affine projections (DESIGN.md §14): canonicalize/swap_factors are views of
# the affine index-map form — asserted equivalent in tests/test_properties.py
# ---------------------------------------------------------------------------


def to_affine(shape: Sequence[int], perm: Sequence[int]):
    """Lift a transpose request to its :class:`repro.core.affine.AffineMap`
    form (the planner's affine IR)."""
    from repro.core import affine  # lazy: affine lazily imports this module

    return affine.AffineMap.from_perm(tuple(shape), tuple(perm))


def affine_canonical(shape: Sequence[int], perm: Sequence[int]) -> Canonical:
    """:func:`canonicalize` recomputed as a projection of the affine form:
    lift to an AffineMap, coalesce with ``affine.merge_runs``, then read the
    movement classification off the merged digits.  The affine merge is
    strictly stronger than :func:`coalesce` (it re-joins runs separated only
    by dropped size-1 axes), so the merged shape may be coarser; the *mode*
    and trailing movement structure agree whenever no size-1 axis splits a
    mergeable run."""
    from repro.core import affine  # lazy: affine lazily imports this module

    m = affine.merge_runs(to_affine(shape, perm))
    cshape, cperm = m.in_digits, m.src
    n = len(cshape)
    if n <= 1 or cperm == tuple(range(n)):
        return Canonical("identity", cshape, cperm, None, None)
    c_in = n - 1
    if cperm[-1] == c_in:
        return Canonical("copy", cshape, cperm, cperm[-2], c_in)
    return Canonical("transpose", cshape, cperm, cperm[-1], c_in)
