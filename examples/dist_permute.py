"""Mesh-aware plan engines on 8 fake host devices (DESIGN.md §10).

Runs the three distributed workloads end to end and checks each against
its single-device oracle:

  1. sharded permute — comm-free when the output sharding rides the
     permutation, ONE tiled all_to_all when it doesn't;
  2. a repeat(k) Jacobi program with ppermute halo exchange — one
     neighbor-pair exchange per k-block, fused §9 kernel per shard;
  3. expert-parallel MoE sort dispatch — the §4 blocked kernels around a
     capacity-bucketed all_to_all pair.

No TPU needed: the mesh is 8 forced host (CPU) devices.

  PYTHONPATH=src python examples/dist_permute.py
"""

import os

# must land before jax initializes its backends (same recipe as
# repro.launch.mesh.fake_device_env / make test-dist)
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import dist_plan as dp  # noqa: E402
from repro.core import stencil as st  # noqa: E402
from repro.launch.mesh import make_mesh_compat  # noqa: E402
from repro.models import moe  # noqa: E402


def main() -> None:
    rng = np.random.default_rng(0)
    mesh = make_mesh_compat((8,), ("x",))
    print(f"devices: {jax.device_count()}  mesh: {dict(dp.mesh_key(mesh))}")

    # 1 — sharded permute: (B, S, D) sharded over B, swap B and S
    x = jnp.asarray(rng.standard_normal((64, 96, 128)), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("x")))
    y_local = dp.shard_permute(xs, (1, 0, 2), mesh=mesh, in_spec=P("x"))
    plan = dp.plan_dist_rearrange(
        dp.mesh_key(mesh), P("x"), None, x.shape, x.dtype, (1, 0, 2)
    )
    print("\npermute, sharding rides the perm:\n ", plan.describe())
    y_a2a = dp.shard_permute(
        xs, (1, 0, 2), mesh=mesh, in_spec=P("x"), out_spec=P(None, None, "x")
    )
    plan = dp.plan_dist_rearrange(
        dp.mesh_key(mesh), P("x"), P(None, None, "x"), x.shape, x.dtype, (1, 0, 2)
    )
    print("permute, resharded output:\n ", plan.describe())
    want = jnp.transpose(x, (1, 0, 2))
    assert jnp.array_equal(y_local, want) and jnp.array_equal(y_a2a, want)
    print("  both bit-identical to the single-device permute")

    # 2 — halo-exchanged stencil: 12 fused Jacobi sweeps, rows sharded
    g = jnp.asarray(rng.standard_normal((256, 130)), jnp.float32)
    gs = jax.device_put(g, NamedSharding(mesh, P("x", None)))
    jacobi = st.Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)), (0.25,) * 4)
    prog = jacobi.repeat(12)
    plan = dp.plan_dist_stencil(
        dp.mesh_key(mesh), "x", g.shape, g.dtype, prog.stages, "reflect"
    )
    print("\nhalo-exchanged repeat(12) Jacobi:\n ", plan.describe())
    got = prog.shard(gs, mesh=mesh, axis="x", boundary="reflect")
    assert jnp.array_equal(got, prog(g, boundary="reflect"))
    print(f"  bit-identical to 12 single-device sweeps "
          f"({len(plan.detail)} k-block(s), one ppermute pair each)")

    # 3 — expert-parallel MoE: tokens and experts sharded over the mesh
    cfg = configs.get_config("deepseek-moe-16b-smoke")
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    xm = jax.random.normal(
        jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32
    ).astype(cfg.np_dtype)
    t = 8 * 16
    plan = dp.plan_dist_moe(
        dp.mesh_key(mesh), "x", t, cfg.d_model, cfg.moe.n_experts,
        t // 8, cfg.moe.top_k, xm.dtype,
    )
    print("\nexpert-parallel MoE sort dispatch:\n ", plan.describe())
    y_ep, _ = moe.moe_sort_ep(params, cfg, xm, mesh=mesh, axis="x", capacity=t // 8)
    y_ref, _ = moe.moe_sort(params, cfg, xm, capacity=t)  # dropless oracle
    assert jnp.array_equal(y_ep, y_ref)
    print("  bit-identical to dropless single-device moe_sort")


if __name__ == "__main__":
    main()
